//! Dynamic batching policy: size buckets, padding, flush-on-timeout,
//! and the continuous-refill variant.
//!
//! The policy is a pure function over queue depth, the oldest
//! request's enqueue time, and the current time — all plain
//! [`Duration`]s since the engine [`Clock`](crate::serve::clock::Clock)
//! epoch, so it is unit-testable with a virtual clock.  The lock-side
//! wait loop that applies it lives in the scheduler
//! ([`crate::serve::sched::Scheduler`]).
//!
//! Forward artifacts are AOT-compiled per batch size, so a batch must
//! be dispatched at one of the available sizes (`buckets`).  A partial
//! batch is rounded up to the smallest bucket that fits and padded by
//! repeating the last real request's image; padded rows are
//! compute-only ballast and never enter the latency accounting
//! ([`FormedBatch::requests`] holds only real requests).
//!
//! Two refill policies ([`SchedPolicy`]):
//!
//! * [`SchedPolicy::FormFirst`] — the PR-1 form-whole-batch-then-
//!   execute rule: dispatch only a full `max_batch`, or whatever is
//!   queued once the oldest request has waited `flush_timeout`.
//! * [`SchedPolicy::Continuous`] — continuous batching: a free worker
//!   immediately takes the largest bucket it can fill *exactly* (zero
//!   padding); the flush timeout only pads out remainders smaller
//!   than the smallest bucket.  Workers never idle while work queues.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::serve::queue::Request;

/// Static batching parameters (derived from the artifact set).
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Dispatchable batch sizes, strictly ascending; the last entry
    /// is the maximum batch and the size-trigger threshold.
    pub buckets: Vec<usize>,
    /// Max time the oldest request may wait before a partial batch is
    /// flushed.
    pub flush_timeout: Duration,
}

impl BatcherConfig {
    pub fn new(buckets: Vec<usize>, flush_timeout: Duration) -> Result<Self> {
        let cfg = BatcherConfig { buckets, flush_timeout };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.buckets.is_empty() {
            bail!("batcher: no batch-size buckets");
        }
        if self.buckets[0] == 0 {
            bail!("batcher: zero-sized bucket");
        }
        if !self.buckets.windows(2).all(|w| w[0] < w[1]) {
            bail!(
                "batcher: buckets {:?} not strictly ascending",
                self.buckets
            );
        }
        Ok(())
    }

    pub fn max_batch(&self) -> usize {
        *self.buckets.last().expect("validated non-empty")
    }

    /// Smallest bucket that fits `take` real requests (`take` must be
    /// ≤ `max_batch`, which every dispatch path guarantees).
    /// Monotone non-decreasing in `take`.
    pub fn bucket_for(&self, take: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= take)
            .unwrap_or_else(|| self.max_batch())
    }

    /// Largest bucket that `pending` requests fill *exactly* (no
    /// padding), or `None` when even the smallest bucket is bigger
    /// than the backlog.
    pub fn largest_fit(&self, pending: usize) -> Option<usize> {
        self.buckets.iter().copied().rev().find(|&b| b <= pending)
    }

    /// Compute-ballast rows the continuous policy executes to clear a
    /// backlog of `n` requests in one go: exact-fill buckets are
    /// taken greedily (zero padding), and the sub-`buckets[0]`
    /// remainder is flushed padded up to the smallest bucket.  This is
    /// the padding model the latency-aware planner
    /// ([`crate::serve::planner`]) scores candidate bucket sets with —
    /// the same `largest_fit`/`bucket_for` rules the dispatch path
    /// applies.
    ///
    /// Taking the largest exact fit repeatedly is, per bucket in
    /// descending order, just `n mod b` (once `n` drops below a
    /// bucket it never comes back up), so this is O(#buckets) even
    /// for astronomically large backlogs.
    pub fn padded_rows(&self, mut n: usize) -> usize {
        for &b in self.buckets.iter().rev() {
            n %= b;
        }
        if n == 0 {
            0
        } else {
            self.bucket_for(n) - n
        }
    }
}

/// How the scheduler refills free worker slots from a lane's queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Form a whole batch before executing: dispatch on a full
    /// `max_batch` or on flush-timeout, never earlier (PR-1
    /// semantics; kept for A/B benchmarking).
    FormFirst,
    /// Continuous batching: dispatch the largest exactly-fillable
    /// bucket the moment a worker frees a slot; flush-timeout only
    /// governs remainders below the smallest bucket.
    Continuous,
}

impl SchedPolicy {
    pub fn parse(s: &str) -> Result<SchedPolicy> {
        Ok(match s {
            "continuous" | "cb" => SchedPolicy::Continuous,
            "form_first" | "legacy" | "batch" => SchedPolicy::FormFirst,
            _ => bail!("unknown sched policy {s:?}"),
        })
    }

    pub fn tag(self) -> &'static str {
        match self {
            SchedPolicy::FormFirst => "form_first",
            SchedPolicy::Continuous => "continuous",
        }
    }
}

/// What a worker should do given the queue's current shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Pop this many requests and dispatch them now.
    Dispatch(usize),
    /// Partial batch pending: sleep until the flush deadline (or an
    /// arrival) and re-decide.
    WaitUntil(Duration),
    /// Queue empty: wait for an arrival.
    WaitForWork,
}

/// The form-first batching policy.  Pure in (config, depth,
/// oldest-enqueue, now); all times are clock-epoch offsets.
pub fn decide(
    cfg: &BatcherConfig,
    pending: usize,
    oldest_enqueued: Option<Duration>,
    now: Duration,
) -> Decision {
    let Some(oldest) = oldest_enqueued else {
        debug_assert_eq!(pending, 0);
        return Decision::WaitForWork;
    };
    let max = cfg.max_batch();
    if pending >= max {
        return Decision::Dispatch(max);
    }
    let flush_at = oldest + cfg.flush_timeout;
    if now >= flush_at {
        Decision::Dispatch(pending)
    } else {
        Decision::WaitUntil(flush_at)
    }
}

/// The refill policy: what a *free worker slot* should take from a
/// lane with `pending` queued requests.  [`SchedPolicy::FormFirst`]
/// defers to [`decide`]; [`SchedPolicy::Continuous`] dispatches any
/// exactly-fillable bucket immediately and only waits on remainders
/// smaller than the smallest bucket.
pub fn refill(
    cfg: &BatcherConfig,
    policy: SchedPolicy,
    pending: usize,
    oldest_enqueued: Option<Duration>,
    now: Duration,
) -> Decision {
    match policy {
        SchedPolicy::FormFirst => decide(cfg, pending, oldest_enqueued, now),
        SchedPolicy::Continuous => {
            let Some(oldest) = oldest_enqueued else {
                debug_assert_eq!(pending, 0);
                return Decision::WaitForWork;
            };
            if pending >= cfg.max_batch() {
                return Decision::Dispatch(cfg.max_batch());
            }
            if let Some(b) = cfg.largest_fit(pending) {
                // Exact fill: zero padding, no reason to wait.
                return Decision::Dispatch(b);
            }
            let flush_at = oldest + cfg.flush_timeout;
            if now >= flush_at {
                Decision::Dispatch(pending)
            } else {
                Decision::WaitUntil(flush_at)
            }
        }
    }
}

/// A dispatched batch: `requests.len()` real rows padded up to
/// `bucket` rows for the compiled executable.
#[derive(Debug)]
pub struct FormedBatch {
    pub requests: Vec<Request>,
    pub bucket: usize,
    /// When the scheduler took this batch off the lane queue
    /// (clock-epoch offset, stamped in `poll_locked`).  The trace
    /// anchor: queue-wait spans end here and service/execute spans
    /// start here, so `queue_wait + service == observed latency` is
    /// an exact identity, on real and virtual clocks alike.
    pub dispatched: Duration,
}

impl FormedBatch {
    /// Number of compute-only padding rows.
    pub fn padding(&self) -> usize {
        self.bucket - self.requests.len()
    }

    /// Flat `f32[bucket, image_elems]` tensor; padding repeats the
    /// last real request's image.
    pub fn padded_images(&self) -> Vec<f32> {
        let elems = self.requests[0].image.len();
        let mut flat = Vec::with_capacity(self.bucket * elems);
        self.padded_images_into(&mut flat);
        flat
    }

    /// [`Self::padded_images`] into a caller-owned buffer (cleared
    /// first) — the worker loop cycles one pooled buffer across
    /// batches instead of allocating per dispatch.
    pub fn padded_images_into(&self, flat: &mut Vec<f32>) {
        flat.clear();
        let elems = self.requests[0].image.len();
        flat.reserve(self.bucket * elems);
        for r in &self.requests {
            debug_assert_eq!(r.image.len(), elems);
            flat.extend_from_slice(&r.image);
        }
        let last = &self.requests[self.requests.len() - 1].image;
        for _ in self.requests.len()..self.bucket {
            flat.extend_from_slice(last);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(buckets: &[usize], flush_ms: u64) -> BatcherConfig {
        BatcherConfig::new(
            buckets.to_vec(),
            Duration::from_millis(flush_ms),
        )
        .unwrap()
    }

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn req(id: u64, elems: usize) -> Request {
        Request::new(
            id,
            vec![id as f32; elems],
            Duration::from_secs(1),
            Duration::ZERO,
        )
    }

    #[test]
    fn config_rejects_bad_buckets() {
        assert!(BatcherConfig::new(vec![], Duration::ZERO).is_err());
        assert!(BatcherConfig::new(vec![0], Duration::ZERO).is_err());
        assert!(BatcherConfig::new(vec![4, 2], Duration::ZERO).is_err());
        assert!(BatcherConfig::new(vec![2, 2], Duration::ZERO).is_err());
        assert!(BatcherConfig::new(vec![1, 2, 8], Duration::ZERO).is_ok());
    }

    #[test]
    fn bucket_rounding() {
        let c = cfg(&[1, 2, 4, 8], 5);
        assert_eq!(c.bucket_for(1), 1);
        assert_eq!(c.bucket_for(3), 4);
        assert_eq!(c.bucket_for(4), 4);
        assert_eq!(c.bucket_for(5), 8);
        assert_eq!(c.bucket_for(8), 8);
        assert_eq!(c.max_batch(), 8);
    }

    #[test]
    fn largest_fit_is_exact() {
        let c = cfg(&[2, 4, 8], 5);
        assert_eq!(c.largest_fit(0), None);
        assert_eq!(c.largest_fit(1), None);
        assert_eq!(c.largest_fit(2), Some(2));
        assert_eq!(c.largest_fit(3), Some(2));
        assert_eq!(c.largest_fit(7), Some(4));
        assert_eq!(c.largest_fit(8), Some(8));
        assert_eq!(c.largest_fit(100), Some(8));
    }

    #[test]
    fn padded_rows_matches_the_greedy_dispatch_policy() {
        let c = cfg(&[2, 4, 8], 5);
        // Exact decompositions pad nothing: 6 = 4 + 2, 12 = 8 + 4.
        assert_eq!(c.padded_rows(0), 0);
        assert_eq!(c.padded_rows(2), 0);
        assert_eq!(c.padded_rows(6), 0);
        assert_eq!(c.padded_rows(12), 0);
        // Sub-smallest remainders pad up to the smallest bucket.
        assert_eq!(c.padded_rows(1), 1);
        assert_eq!(c.padded_rows(5), 1); // 4 + (1 → 2)
        assert_eq!(c.padded_rows(9), 1); // 8 + (1 → 2)
        // A bucket-1 set never pads anything.
        let c1 = cfg(&[1, 4], 5);
        for n in 0..20 {
            assert_eq!(c1.padded_rows(n), 0);
        }
        // O(#buckets): a huge backlog must not spin.
        assert_eq!(c.padded_rows(1_000_000_001), 1); // 1e9+1 ≡ 1 mod 8,4,2
    }

    #[test]
    fn empty_queue_waits_for_work() {
        let c = cfg(&[8], 5);
        assert_eq!(decide(&c, 0, None, ms(3)), Decision::WaitForWork);
        for p in [SchedPolicy::FormFirst, SchedPolicy::Continuous] {
            assert_eq!(refill(&c, p, 0, None, ms(3)), Decision::WaitForWork);
        }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let c = cfg(&[8], 5);
        // Even a brand-new full batch goes out at once.
        assert_eq!(decide(&c, 8, Some(ms(10)), ms(10)), Decision::Dispatch(8));
        // More than a batch waiting: still dispatch max, rest stays.
        assert_eq!(decide(&c, 13, Some(ms(10)), ms(10)), Decision::Dispatch(8));
    }

    #[test]
    fn partial_batch_waits_until_flush_deadline() {
        let c = cfg(&[8], 5);
        // Before the deadline: wait exactly until it.
        match decide(&c, 3, Some(ms(10)), ms(12)) {
            Decision::WaitUntil(at) => assert_eq!(at, ms(15)),
            other => panic!("expected WaitUntil, got {other:?}"),
        }
    }

    #[test]
    fn flush_fires_at_the_deadline() {
        let c = cfg(&[8], 5);
        // At and after the deadline: flush the partial batch.
        assert_eq!(decide(&c, 3, Some(ms(10)), ms(15)), Decision::Dispatch(3));
        assert_eq!(decide(&c, 3, Some(ms(10)), ms(22)), Decision::Dispatch(3));
    }

    #[test]
    fn continuous_dispatches_exact_fits_without_waiting() {
        let c = cfg(&[2, 4, 8], 500);
        let p = SchedPolicy::Continuous;
        // Brand-new backlog of 5: take the exactly-fillable 4 now.
        assert_eq!(refill(&c, p, 5, Some(ms(0)), ms(0)), Decision::Dispatch(4));
        assert_eq!(refill(&c, p, 2, Some(ms(0)), ms(0)), Decision::Dispatch(2));
        assert_eq!(refill(&c, p, 9, Some(ms(0)), ms(0)), Decision::Dispatch(8));
        // Below the smallest bucket: flush semantics apply.
        assert_eq!(
            refill(&c, p, 1, Some(ms(0)), ms(0)),
            Decision::WaitUntil(ms(500))
        );
        assert_eq!(refill(&c, p, 1, Some(ms(0)), ms(500)), Decision::Dispatch(1));
        // FormFirst would have waited on all of these partials.
        assert_eq!(
            refill(&c, SchedPolicy::FormFirst, 5, Some(ms(0)), ms(0)),
            Decision::WaitUntil(ms(500))
        );
    }

    #[test]
    fn policy_parse_roundtrip() {
        assert_eq!(
            SchedPolicy::parse("continuous").unwrap(),
            SchedPolicy::Continuous
        );
        assert_eq!(
            SchedPolicy::parse("form_first").unwrap(),
            SchedPolicy::FormFirst
        );
        assert_eq!(
            SchedPolicy::parse("legacy").unwrap(),
            SchedPolicy::FormFirst
        );
        assert!(SchedPolicy::parse("eager").is_err());
        assert_eq!(SchedPolicy::Continuous.tag(), "continuous");
    }

    #[test]
    fn padded_images_repeat_last_real_row() {
        let batch = FormedBatch {
            requests: vec![req(0, 4), req(1, 4), req(2, 4)],
            bucket: 8,
            dispatched: Duration::ZERO,
        };
        assert_eq!(batch.padding(), 5);
        let flat = batch.padded_images();
        assert_eq!(flat.len(), 8 * 4);
        assert_eq!(&flat[..4], &[0.0; 4]);
        assert_eq!(&flat[4..8], &[1.0; 4]);
        // rows 2..8 all repeat request 2's image
        for row in 2..8 {
            assert_eq!(&flat[row * 4..(row + 1) * 4], &[2.0; 4]);
        }
    }

    #[test]
    fn exact_batch_has_no_padding() {
        let batch = FormedBatch {
            requests: (0..4).map(|i| req(i, 2)).collect(),
            bucket: 4,
            dispatched: Duration::ZERO,
        };
        assert_eq!(batch.padding(), 0);
        assert_eq!(batch.padded_images().len(), 8);
    }
}
