//! Deterministic load generation for closed- and open-loop runs.
//!
//! Arrivals follow a Poisson process: inter-arrival gaps are sampled
//! from Exp(rate) by inverse CDF over the repo's deterministic
//! [`Rng`] — the same (rate, seed) always offers bit-identical load,
//! so serving benchmarks are reproducible run to run.
//!
//! Multi-lane runs use [`merged_schedule`]: each lane gets its own
//! seeded Poisson stream and the streams are merge-sorted into one
//! timeline the single producer thread replays, pacing itself on the
//! engine [`Clock`] via [`pace`] — which is what makes the arrival
//! process itself virtual-clock-simulable.

use std::time::Duration;

use crate::serve::clock::Clock;
use crate::util::rng::Rng;

/// Arrival offsets (from generator start) for `n` requests at
/// `rate_per_s` requests/second.  `rate_per_s <= 0` means
/// back-to-back arrivals (all offsets zero — the closed-loop
/// saturation case).
pub fn poisson_offsets(n: u64, rate_per_s: f64, seed: u64) -> Vec<Duration> {
    let n = n as usize;
    if rate_per_s <= 0.0 {
        return vec![Duration::ZERO; n];
    }
    let mut rng = Rng::new(seed ^ 0x5E4E_0A7E_11FE_ED5D);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // 1-U ∈ (0, 1] keeps ln away from 0.
        let u = 1.0 - rng.next_f64();
        t += -u.ln() / rate_per_s;
        out.push(Duration::from_secs_f64(t));
    }
    out
}

/// One multiplexed arrival: when, which lane, and the lane-local
/// request index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    pub at: Duration,
    pub lane: usize,
    /// Lane-local request index (becomes the request id).
    pub idx: u64,
}

/// Merge independent per-lane Poisson streams — `(requests, rate)`
/// per lane — into one ascending timeline.  Each lane's stream is
/// seeded from `seed` and its lane index, so adding a lane never
/// perturbs another lane's arrivals.
pub fn merged_schedule(
    lanes: &[(u64, f64)],
    seed: u64,
) -> Vec<Arrival> {
    let mut out = Vec::new();
    for (lane, &(n, rate)) in lanes.iter().enumerate() {
        let lane_seed =
            seed.wrapping_add((lane as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        for (idx, at) in poisson_offsets(n, rate, lane_seed)
            .into_iter()
            .enumerate()
        {
            out.push(Arrival { at, lane, idx: idx as u64 });
        }
    }
    // Deterministic keyed sort; simultaneous arrivals (the rate ≤ 0
    // back-to-back case) interleave round-robin across lanes rather
    // than lane-major, so a saturating multi-lane offer actually
    // contends from the first request.
    out.sort_by_key(|a| (a.at, a.idx, a.lane));
    out
}

/// Block on `clock` until `start + offset` (no-op when already past).
/// The producer thread calls this between arrivals.
pub fn pace(clock: &dyn Clock, start: Duration, offset: Duration) {
    clock.sleep_until(start + offset);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::clock::VirtualClock;

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(poisson_offsets(50, 100.0, 7), poisson_offsets(50, 100.0, 7));
        assert_ne!(poisson_offsets(50, 100.0, 7), poisson_offsets(50, 100.0, 8));
    }

    #[test]
    fn monotonically_increasing() {
        let offs = poisson_offsets(200, 500.0, 3);
        assert!(offs.windows(2).all(|w| w[0] <= w[1]));
        assert!(offs[0] > Duration::ZERO);
    }

    #[test]
    fn mean_rate_matches() {
        let rate = 1000.0;
        let n = 20_000u64;
        let offs = poisson_offsets(n, rate, 11);
        // Last offset ≈ n/rate seconds (law of large numbers).
        let expect = n as f64 / rate;
        let got = offs.last().unwrap().as_secs_f64();
        assert!(
            (got - expect).abs() / expect < 0.05,
            "expected ≈{expect}s of arrivals, got {got}s"
        );
    }

    #[test]
    fn zero_rate_is_back_to_back() {
        let offs = poisson_offsets(5, 0.0, 1);
        assert_eq!(offs, vec![Duration::ZERO; 5]);
    }

    #[test]
    fn merged_schedule_is_sorted_and_complete() {
        let sched = merged_schedule(&[(40, 200.0), (25, 900.0)], 5);
        assert_eq!(sched.len(), 65);
        assert!(sched.windows(2).all(|w| w[0].at <= w[1].at));
        let lane0: Vec<u64> =
            sched.iter().filter(|a| a.lane == 0).map(|a| a.idx).collect();
        let lane1: Vec<u64> =
            sched.iter().filter(|a| a.lane == 1).map(|a| a.idx).collect();
        // Per-lane indices stay in order and are gap-free.
        assert_eq!(lane0, (0..40).collect::<Vec<_>>());
        assert_eq!(lane1, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn back_to_back_lanes_interleave() {
        // All offsets are zero at rate 0: the merged order must
        // round-robin the lanes, not dump lane 0 first.
        let sched = merged_schedule(&[(3, 0.0), (3, 0.0)], 1);
        let order: Vec<(usize, u64)> =
            sched.iter().map(|a| (a.lane, a.idx)).collect();
        assert_eq!(
            order,
            vec![(0, 0), (1, 0), (0, 1), (1, 1), (0, 2), (1, 2)]
        );
    }

    #[test]
    fn merged_schedule_lane_streams_are_independent() {
        let solo = merged_schedule(&[(30, 400.0)], 9);
        let duo = merged_schedule(&[(30, 400.0), (30, 400.0)], 9);
        let duo_lane0: Vec<Arrival> =
            duo.into_iter().filter(|a| a.lane == 0).collect();
        assert_eq!(solo, duo_lane0);
    }

    #[test]
    fn pace_uses_the_clock_not_real_sleeps() {
        // On a virtual clock already past the target, pace returns
        // immediately — no wall-clock wait.
        let clock = VirtualClock::new();
        clock.set(Duration::from_millis(10));
        pace(&clock, Duration::ZERO, Duration::from_millis(5));
        assert_eq!(clock.now(), Duration::from_millis(10));
    }
}
