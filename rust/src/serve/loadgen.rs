//! Deterministic load generation for closed- and open-loop runs.
//!
//! Arrivals follow a Poisson process: inter-arrival gaps are sampled
//! from Exp(rate) by inverse CDF over the repo's deterministic
//! [`Rng`] — the same (rate, seed) always offers bit-identical load,
//! so serving benchmarks are reproducible run to run.

use std::time::Duration;

use crate::util::rng::Rng;

/// Arrival offsets (from generator start) for `n` requests at
/// `rate_per_s` requests/second.  `rate_per_s <= 0` means
/// back-to-back arrivals (all offsets zero — the closed-loop
/// saturation case).
pub fn poisson_offsets(n: u64, rate_per_s: f64, seed: u64) -> Vec<Duration> {
    let n = n as usize;
    if rate_per_s <= 0.0 {
        return vec![Duration::ZERO; n];
    }
    let mut rng = Rng::new(seed ^ 0x5E4E_0A7E_11FE_ED5D);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // 1-U ∈ (0, 1] keeps ln away from 0.
        let u = 1.0 - rng.next_f64();
        t += -u.ln() / rate_per_s;
        out.push(Duration::from_secs_f64(t));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(poisson_offsets(50, 100.0, 7), poisson_offsets(50, 100.0, 7));
        assert_ne!(poisson_offsets(50, 100.0, 7), poisson_offsets(50, 100.0, 8));
    }

    #[test]
    fn monotonically_increasing() {
        let offs = poisson_offsets(200, 500.0, 3);
        assert!(offs.windows(2).all(|w| w[0] <= w[1]));
        assert!(offs[0] > Duration::ZERO);
    }

    #[test]
    fn mean_rate_matches() {
        let rate = 1000.0;
        let n = 20_000u64;
        let offs = poisson_offsets(n, rate, 11);
        // Last offset ≈ n/rate seconds (law of large numbers).
        let expect = n as f64 / rate;
        let got = offs.last().unwrap().as_secs_f64();
        assert!(
            (got - expect).abs() / expect < 0.05,
            "expected ≈{expect}s of arrivals, got {got}s"
        );
    }

    #[test]
    fn zero_rate_is_back_to_back() {
        let offs = poisson_offsets(5, 0.0, 1);
        assert_eq!(offs, vec![Duration::ZERO; 5]);
    }
}
