//! Latency-aware bucket planner: which forward batch sizes to
//! AOT-compile, and which flush timeouts to run, per lane.
//!
//! The serving engine can only dispatch batches at the sizes that
//! were AOT-compiled (`serve::batcher` buckets), and until this
//! module the set was static: whatever artifacts existed, with one
//! global flush timeout — so the scheduler could not trade padding
//! waste against flush latency per (model, precision) lane.  The
//! planner closes that gap.  Given an *offered-load profile* — per
//! lane: a Poisson arrival rate, an optional dispatch-size
//! distribution, and a p99 deadline (SLO) — it searches the candidate
//! bucket subsets and picks, for every lane,
//!
//! 1. the bucket set minimizing **expected padding waste**, and
//! 2. the largest **flush timeout** that still meets the deadline
//!    (a longer flush window lets sub-bucket remainders grow into
//!    exact fills, which is the padding/latency trade at the heart of
//!    the batcher),
//!
//! subject to the **p99 budget** `safety × deadline` under the same
//! linear service model (`service(b) = overhead + per_row × b`) the
//! virtual-clock harness [`simulate`](crate::serve::sched::simulate)
//! executes batches with — so a plan's feasibility claim can be
//! checked *exactly* in `rust/tests/serve_sim.rs`, no tolerances.
//!
//! # The latency model
//!
//! For a candidate subset with smallest bucket `b_min` and largest
//! `b_max`, a request's p99 latency is bounded by three terms:
//!
//! * **queueing** `Wq` — the M/D/1 mean residual wait
//!   `service(b_max) × ρ / (1 − ρ) / 2` inflated by
//!   [`P99_WAIT_FACTOR`] `= ln(100) ≈ 4.6`, the multiplier that maps
//!   an M/M/1 mean wait to its 99th percentile (an upper envelope
//!   for M/D/1's lighter-tailed wait) — a *p99* budget must be
//!   checked against a p99 wait, not a mean.  Utilization is
//!   `ρ = rate / share-capacity(b_max)`, where a lane's
//!   *share-capacity* is the throughput of its weighted-deficit
//!   guaranteed slice of the pool, `capacity(b_max) × weight /
//!   Σ weights` — the service floor the scheduler honours even when
//!   every other lane is saturated (work-conserving scheduling can
//!   only do better, so feasibility is sound, not optimistic).  Zero
//!   for back-to-back lanes (rate ≤ 0), where latency is
//!   throughput-bound, not SLO-bound;
//! * **flush exposure** — a lone request below `b_min` waits the full
//!   flush timeout before it is padded out; zero when `b_min == 1`
//!   (any backlog exact-fills immediately under continuous refill);
//! * **service** `service(b_max)` — the worst batch it can ride in.
//!
//! A subset is feasible when those terms fit the budget; the flush
//! timeout takes all the slack that is left (clamped to
//! [`PlannerConfig::max_flush`]).  Subsets that cannot keep up with
//! the offered rate (ρ at or above 99 % of capacity, where the
//! queueing term diverges) are rejected outright.
//!
//! # The padding model
//!
//! Expected padding is scored with the *dispatch policy itself*:
//! [`BatcherConfig::padded_rows`] replays the greedy
//! largest-exact-fit-then-pad rule on every size in the lane's
//! distribution (explicit, or Poisson over the flush window derived
//! from the rate).  Ties break toward higher per-row throughput at
//! `b_max`, then fewer compiled artifacts, then the smaller `b_max`
//! — all deterministic, so the same profile always yields the same
//! plan.
//!
//! A lane whose deadline no candidate bucket can meet — or whose rate
//! no admissible bucket can absorb — gets a
//! [`PlanVerdict::Infeasible`] with the reason; the planner reports,
//! it never loops.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::serve::batcher::BatcherConfig;
use crate::serve::sched::LaneSpec;
use crate::util::human_duration;

/// The linear batch service model shared with the simulation harness:
/// executing a bucket-`b` batch takes `overhead + per_row × b`.
#[derive(Debug, Clone, Copy)]
pub struct ServiceModel {
    pub overhead: Duration,
    pub per_row: Duration,
}

impl ServiceModel {
    /// Service time of one batch of `rows` rows.
    pub fn service(&self, rows: usize) -> Duration {
        self.overhead + self.per_row * rows as u32
    }

    /// Sustained full-batch throughput of `workers` workers
    /// dispatching bucket-`bucket` batches, in requests/second.
    pub fn capacity_rps(&self, bucket: usize, workers: usize) -> f64 {
        let per_batch = self.service(bucket).as_secs_f64();
        if per_batch <= 0.0 {
            f64::INFINITY
        } else {
            workers as f64 * bucket as f64 / per_batch
        }
    }
}

/// One lane's offered load and SLO — what
/// [`LaneConfig`](crate::config::LaneConfig) carries, decoupled from
/// the config layer.
#[derive(Debug, Clone)]
pub struct LaneProfile {
    pub name: String,
    /// Poisson arrival rate, req/s; ≤ 0 means back-to-back
    /// (throughput-planned, not latency-planned).
    pub rate: f64,
    /// p99 end-to-end deadline.
    pub deadline: Duration,
    /// Weighted-deficit service weight (≥ 1), passed through to the
    /// resulting [`LaneSpec`].
    pub weight: u64,
    /// Explicit `(size, weight)` dispatch-size distribution; empty ⇒
    /// derived from `rate` as Poisson over the flush window.
    pub size_dist: Vec<(usize, f64)>,
}

/// Search-space knobs.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Bucket sizes that *could* be AOT-compiled, strictly ascending
    /// (at most 16 — the subset search is exhaustive).
    pub candidates: Vec<usize>,
    /// Worker-pool size the capacity model assumes.
    pub workers: usize,
    /// Max buckets to compile per lane; 0 = unlimited.
    pub max_compiled: usize,
    /// Fraction of each deadline the plan may spend, in (0, 1].
    pub safety: f64,
    /// Flush-timeout ceiling (the legacy global flush makes a natural
    /// one).
    pub max_flush: Duration,
}

/// Predicted behaviour of a chosen lane plan.
#[derive(Debug, Clone, Copy)]
pub struct PlanEstimate {
    /// Conservative p99 bound: queueing + flush exposure + worst
    /// batch service.
    pub p99: Duration,
    /// Expected padded rows / executed rows under the size
    /// distribution.
    pub padding_fraction: f64,
    /// Offered rate over the lane's weight-share capacity at the
    /// largest chosen bucket.
    pub utilization: f64,
}

/// Whether a lane's SLO is achievable at all.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanVerdict {
    Feasible,
    /// No candidate bucket subset meets the SLO; `reason` says which
    /// constraint failed (deadline vs capacity).
    Infeasible { reason: String },
}

/// The planner's answer for one lane.
#[derive(Debug, Clone)]
pub struct LanePlan {
    pub name: String,
    pub weight: u64,
    pub rate: f64,
    pub deadline: Duration,
    /// Bucket sizes to AOT-compile and dispatch at, ascending; empty
    /// when infeasible.
    pub buckets: Vec<usize>,
    /// Per-lane flush timeout (replaces the global one).
    pub flush_timeout: Duration,
    pub predicted: PlanEstimate,
    pub verdict: PlanVerdict,
}

impl LanePlan {
    pub fn is_feasible(&self) -> bool {
        matches!(self.verdict, PlanVerdict::Feasible)
    }

    /// The batcher configuration this plan prescribes.
    pub fn batcher(&self) -> Result<BatcherConfig> {
        if !self.is_feasible() {
            bail!("lane {}: no feasible plan to build a batcher from", self.name);
        }
        BatcherConfig::new(self.buckets.clone(), self.flush_timeout)
    }

    /// A ready-to-schedule [`LaneSpec`] carrying the planned buckets,
    /// flush timeout, weight, and deadline.
    pub fn lane_spec(&self, queue_capacity: usize) -> Result<LaneSpec> {
        Ok(LaneSpec {
            name: self.name.clone(),
            weight: self.weight,
            batcher: self.batcher()?,
            queue_capacity,
            deadline: self.deadline,
        })
    }
}

/// A full multi-lane plan.
#[derive(Debug, Clone)]
pub struct Plan {
    pub lanes: Vec<LanePlan>,
}

impl Plan {
    /// True when every lane got a feasible bucket set.
    pub fn is_feasible(&self) -> bool {
        self.lanes.iter().all(|l| l.is_feasible())
    }

    /// Union of every lane's planned buckets, ascending — the compile
    /// work list for `make artifacts`.
    pub fn all_buckets(&self) -> Vec<usize> {
        let mut out: Vec<usize> =
            self.lanes.iter().flat_map(|l| l.buckets.iter().copied()).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Human-readable plan summary on stdout (`mpx serve --plan`).
    pub fn print(&self) {
        for l in &self.lanes {
            match &l.verdict {
                PlanVerdict::Feasible => {
                    println!(
                        "[plan] lane {}: buckets {:?}, flush {}, weight {}",
                        l.name,
                        l.buckets,
                        human_duration(l.flush_timeout),
                        l.weight,
                    );
                    println!(
                        "       offered {:.1} req/s (util {:.0}%) | predicted \
                         p99 {} ≤ deadline {} | expected padding {:.1}%",
                        l.rate.max(0.0),
                        l.predicted.utilization * 100.0,
                        human_duration(l.predicted.p99),
                        human_duration(l.deadline),
                        l.predicted.padding_fraction * 100.0,
                    );
                }
                PlanVerdict::Infeasible { reason } => {
                    println!("[plan] lane {}: INFEASIBLE — {reason}", l.name);
                }
            }
        }
        if !self.lanes.is_empty() {
            println!(
                "[plan] compile work list (all lanes): {:?}",
                self.all_buckets()
            );
        }
    }
}

/// Power-of-two candidate buckets up to (and including) `max_batch` —
/// the same ladder `discover_buckets` probes artifacts for.
pub fn pow2_candidates(max_batch: usize) -> Vec<usize> {
    if max_batch == 0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut b = 1usize;
    while b < max_batch {
        out.push(b);
        b *= 2;
    }
    out.push(max_batch);
    out
}

/// Plan every lane in `lanes`.  Each lane is sized against its
/// *weight-share* of the worker pool — the service floor the
/// weighted-deficit scheduler guarantees it even when every other
/// lane is saturated — so a `Feasible` multi-lane plan is servable
/// under full contention, and work-conserving scheduling only makes
/// reality better than the prediction.  An empty profile yields an
/// empty plan.  Malformed *configuration* is an error; an unmeetable
/// *SLO* is a [`PlanVerdict::Infeasible`] on that lane, reported
/// rather than retried.
pub fn plan(
    cfg: &PlannerConfig,
    model: &ServiceModel,
    lanes: &[LaneProfile],
) -> Result<Plan> {
    let models = vec![model.clone(); lanes.len()];
    plan_with_models(cfg, &models, lanes)
}

/// [`plan`] with one [`ServiceModel`] per lane — the calibrated path,
/// where each lane's `(overhead, per_row)` was fitted from its own
/// measured executions and lanes no longer share a single model.
pub fn plan_with_models(
    cfg: &PlannerConfig,
    models: &[ServiceModel],
    lanes: &[LaneProfile],
) -> Result<Plan> {
    if models.len() != lanes.len() {
        bail!(
            "planner: {} service models for {} lanes",
            models.len(),
            lanes.len()
        );
    }
    if cfg.candidates.is_empty() {
        bail!("planner: no candidate buckets");
    }
    if cfg.candidates.len() > 16 {
        bail!(
            "planner: {} candidate buckets — the exhaustive subset search \
             caps at 16",
            cfg.candidates.len()
        );
    }
    if cfg.candidates[0] == 0 {
        bail!("planner: zero-sized candidate bucket");
    }
    if !cfg.candidates.windows(2).all(|w| w[0] < w[1]) {
        bail!(
            "planner: candidates {:?} not strictly ascending",
            cfg.candidates
        );
    }
    if cfg.workers == 0 {
        bail!("planner: workers must be ≥ 1");
    }
    if !(cfg.safety > 0.0 && cfg.safety <= 1.0) {
        bail!("planner: safety {} outside (0, 1]", cfg.safety);
    }
    let total_weight: u64 = lanes.iter().map(|l| l.weight).sum();
    let planned = lanes
        .iter()
        .zip(models)
        .map(|(lane, model)| {
            let share = lane.weight as f64 / total_weight.max(1) as f64;
            plan_lane(cfg, model, lane, share)
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Plan { lanes: planned })
}

/// Highest utilization a plan may run at: above this the queueing
/// approximation diverges, so such subsets count as capacity
/// failures.
const MAX_UTILIZATION: f64 = 0.99;

/// Mean-wait → p99-wait multiplier: `ln(100)`, exact for the
/// exponential M/M/1 waiting-time tail and an upper envelope for
/// M/D/1's lighter tail.  The deadline is a p99 budget, so the
/// queueing term must be a p99 wait, not a mean.
const P99_WAIT_FACTOR: f64 = 4.605_170_185_988_091;

/// Lexicographic plan score, smaller is better: padding first, then
/// per-request service cost at the largest bucket (throughput), then
/// compile count, then the smaller largest-bucket for determinism.
struct Score {
    pad_frac: f64,
    per_request: f64,
    compiled: usize,
    b_max: usize,
}

impl Score {
    fn beats(&self, other: &Score) -> bool {
        self.pad_frac
            .total_cmp(&other.pad_frac)
            .then(self.per_request.total_cmp(&other.per_request))
            .then(self.compiled.cmp(&other.compiled))
            .then(self.b_max.cmp(&other.b_max))
            == std::cmp::Ordering::Less
    }
}

fn infeasible(
    lane: &LaneProfile,
    utilization: f64,
    reason: String,
) -> LanePlan {
    LanePlan {
        name: lane.name.clone(),
        weight: lane.weight,
        rate: lane.rate,
        deadline: lane.deadline,
        buckets: Vec::new(),
        flush_timeout: Duration::ZERO,
        predicted: PlanEstimate {
            p99: Duration::ZERO,
            padding_fraction: 0.0,
            utilization,
        },
        verdict: PlanVerdict::Infeasible { reason },
    }
}

/// Plan one lane against `share` of the pool's capacity — its
/// weighted-deficit guaranteed fraction (1.0 for a lone lane).
fn plan_lane(
    cfg: &PlannerConfig,
    model: &ServiceModel,
    lane: &LaneProfile,
    share: f64,
) -> Result<LanePlan> {
    if lane.name.is_empty() {
        bail!("planner: lane with an empty name");
    }
    if lane.weight == 0 {
        bail!("planner: lane {} has zero weight", lane.name);
    }
    if !lane.rate.is_finite() {
        bail!("planner: lane {} rate must be finite", lane.name);
    }
    for &(s, w) in &lane.size_dist {
        if s == 0 || !(w > 0.0) || !w.is_finite() {
            bail!(
                "planner: lane {} size_dist entry ({s}, {w}) — sizes must be \
                 ≥ 1 and weights finite and > 0",
                lane.name
            );
        }
    }
    let budget = lane.deadline.mul_f64(cfg.safety);

    // 1. Latency admissibility: a bucket whose bare service time blows
    //    the budget can never appear in a feasible subset.
    let admissible: Vec<usize> = cfg
        .candidates
        .iter()
        .copied()
        .filter(|&b| model.service(b) <= budget)
        .collect();
    if admissible.is_empty() {
        let b0 = cfg.candidates[0];
        return Ok(infeasible(
            lane,
            0.0,
            format!(
                "service time {} of the smallest candidate bucket b{} \
                 exceeds the p99 budget {} ({:.0}% of the {} deadline) — no \
                 bucket can meet this SLO on this service model",
                human_duration(model.service(b0)),
                b0,
                human_duration(budget),
                cfg.safety * 100.0,
                human_duration(lane.deadline),
            ),
        ));
    }
    let b_top = *admissible.last().expect("non-empty admissible");
    let cap_top = model.capacity_rps(b_top, cfg.workers) * share;

    // 2. Exhaustive subset search (≤ 2^16) for the padding-minimal
    //    feasible plan.
    let n = admissible.len();
    let mut best: Option<(Score, Vec<usize>, Duration, PlanEstimate)> = None;
    let mut capacity_fail = false;
    for mask in 1u32..(1u32 << n) {
        let subset: Vec<usize> = (0..n)
            .filter(|i| mask & (1 << i) != 0)
            .map(|i| admissible[i])
            .collect();
        if cfg.max_compiled > 0 && subset.len() > cfg.max_compiled {
            continue;
        }
        let b_min = subset[0];
        let b_max = *subset.last().expect("non-empty subset");
        let svc_max = model.service(b_max);

        // Throughput: the lane's *guaranteed* slice of the pool must
        // absorb its offered rate with a sliver of headroom — at
        // ≥ 99 % utilization the queueing term explodes and no p99
        // target is realistic (and the division below would be
        // numerically meaningless).
        let capacity = model.capacity_rps(b_max, cfg.workers) * share;
        let rho = if lane.rate > 0.0 { lane.rate / capacity } else { 0.0 };
        if rho >= MAX_UTILIZATION {
            capacity_fail = true;
            continue;
        }

        // Latency: p99 queueing + flush exposure + service within
        // budget.  Mean residual wait × the p99 tail multiplier —
        // the budget is a 99th percentile, so the wait term is too.
        let wq = if rho > 0.0 {
            svc_max.mul_f64(rho / (1.0 - rho) / 2.0 * P99_WAIT_FACTOR)
        } else {
            Duration::ZERO
        };
        let Some(slack) = budget.checked_sub(svc_max + wq) else {
            continue;
        };
        // All remaining slack goes to the flush window (more time for
        // remainders to grow into exact fills ⇒ less padding), capped
        // by the configured ceiling.  With b_min == 1 the flush can
        // never fire, so it costs no latency.
        let flush = slack.min(cfg.max_flush);
        let exposure = if b_min > 1 { flush } else { Duration::ZERO };
        let p99 = wq + exposure + svc_max;

        let batcher = BatcherConfig::new(subset.clone(), flush)?;
        let dist = effective_dist(lane, flush, b_max);
        let pad_frac = padding_fraction(&batcher, &dist);
        let score = Score {
            pad_frac,
            per_request: svc_max.as_secs_f64() / b_max as f64,
            compiled: subset.len(),
            b_max,
        };
        if best.as_ref().map_or(true, |(b, ..)| score.beats(b)) {
            let est = PlanEstimate {
                p99,
                padding_fraction: pad_frac,
                utilization: rho,
            };
            best = Some((score, subset, flush, est));
        }
    }

    let Some((_, buckets, flush, predicted)) = best else {
        let reason = if capacity_fail {
            format!(
                "offered {:.1} req/s is at or above {:.0}% of the lane's \
                 {:.1} req/s guaranteed capacity ({:.0}% weight share of {} \
                 workers at the largest deadline-admissible bucket b{b_top}) \
                 — add workers, raise the lane weight, or relax the deadline",
                lane.rate,
                MAX_UTILIZATION * 100.0,
                cap_top,
                share * 100.0,
                cfg.workers,
            )
        } else {
            format!(
                "no bucket subset fits the p99 budget {}: queueing plus \
                 service exceed it at every deadline-admissible bucket",
                human_duration(budget),
            )
        };
        return Ok(infeasible(lane, lane.rate.max(0.0) / cap_top, reason));
    };
    Ok(LanePlan {
        name: lane.name.clone(),
        weight: lane.weight,
        rate: lane.rate,
        deadline: lane.deadline,
        buckets,
        flush_timeout: flush,
        predicted,
        verdict: PlanVerdict::Feasible,
    })
}

/// The dispatch-size distribution to score padding against: the
/// explicit one when given; a point mass at the largest bucket for
/// back-to-back lanes (saturated backlogs exact-fill); otherwise
/// Poisson(rate × flush window) truncated at `cap` with the tail mass
/// lumped into `cap`.
fn effective_dist(
    lane: &LaneProfile,
    flush: Duration,
    cap: usize,
) -> Vec<(usize, f64)> {
    if !lane.size_dist.is_empty() {
        return lane.size_dist.clone();
    }
    if lane.rate <= 0.0 {
        return vec![(cap, 1.0)];
    }
    poisson_sizes(lane.rate * flush.as_secs_f64(), cap)
}

/// `P(dispatch size = s)` for `s ∈ 1..=cap` under Poisson(λ) arrivals
/// in one flush window, conditioned on at least one arrival; mass at
/// `≥ cap` lumps into `cap` (a deep backlog dispatches full buckets).
fn poisson_sizes(lambda: f64, cap: usize) -> Vec<(usize, f64)> {
    if cap <= 1 || lambda <= 0.0 || !lambda.is_finite() {
        return vec![(1, 1.0)];
    }
    // The pmf is evaluated in log space (ln P(s) = s·ln λ − λ − ln s!)
    // because exp(−λ) underflows to zero for λ ≳ 746 and the old
    // multiplicative recurrence seeded from it zeroed every head mass
    // — including the ones that are individually representable.  Each
    // term is a true probability (≤ 1), so it exponentiates directly
    // with no max-shift; the ≥ cap tail lump takes the remaining mass.
    let ln_lambda = lambda.ln();
    let mut ln_fact = 0.0; // ln(s!)
    let mut acc = (-lambda).exp(); // P(0); 0 when it underflows is fine
    let mut out = Vec::with_capacity(cap);
    for s in 1..cap {
        ln_fact += (s as f64).ln();
        let p = (s as f64 * ln_lambda - lambda - ln_fact).exp();
        out.push((s, p));
        acc += p;
    }
    out.push((cap, (1.0 - acc).max(0.0)));
    let total: f64 = out.iter().map(|(_, w)| w).sum();
    if total <= 0.0 {
        return vec![(1, 1.0)];
    }
    for (_, w) in &mut out {
        *w /= total;
    }
    out
}

/// Expected padded rows over executed rows when clearing dispatches
/// drawn from `dist` with `batcher`'s greedy policy — the quantity
/// the subset search minimizes (same definition as
/// `ServeReport::padding_fraction`).
fn padding_fraction(batcher: &BatcherConfig, dist: &[(usize, f64)]) -> f64 {
    let mut pad = 0.0;
    let mut real = 0.0;
    for &(s, w) in dist {
        pad += w * batcher.padded_rows(s) as f64;
        real += w * s as f64;
    }
    if real + pad <= 0.0 {
        0.0
    } else {
        pad / (real + pad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn model_1_1() -> ServiceModel {
        // service(b) = 1 ms + b ms — easy mental arithmetic.
        ServiceModel { overhead: ms(1), per_row: ms(1) }
    }

    fn pcfg(candidates: &[usize]) -> PlannerConfig {
        PlannerConfig {
            candidates: candidates.to_vec(),
            workers: 1,
            max_compiled: 0,
            safety: 0.9,
            max_flush: ms(20),
        }
    }

    fn profile(name: &str, rate: f64, deadline: Duration) -> LaneProfile {
        LaneProfile {
            name: name.into(),
            rate,
            deadline,
            weight: 1,
            size_dist: Vec::new(),
        }
    }

    #[test]
    fn empty_profile_plans_empty() {
        let p = plan(&pcfg(&[1, 2, 4, 8]), &model_1_1(), &[]).unwrap();
        assert!(p.lanes.is_empty());
        assert!(p.is_feasible());
        assert!(p.all_buckets().is_empty());
    }

    #[test]
    fn rejects_malformed_search_space() {
        let m = model_1_1();
        let lanes = [profile("a", 10.0, ms(100))];
        assert!(plan(&pcfg(&[]), &m, &lanes).is_err());
        assert!(plan(&pcfg(&[0, 2]), &m, &lanes).is_err());
        assert!(plan(&pcfg(&[4, 2]), &m, &lanes).is_err());
        let mut too_many = pcfg(&[1; 1]);
        too_many.candidates = (1..=17).collect();
        assert!(plan(&too_many, &m, &lanes).is_err());
        let mut bad_safety = pcfg(&[1, 2]);
        bad_safety.safety = 1.5;
        assert!(plan(&bad_safety, &m, &lanes).is_err());
        let mut no_workers = pcfg(&[1, 2]);
        no_workers.workers = 0;
        assert!(plan(&no_workers, &m, &lanes).is_err());
    }

    #[test]
    fn single_candidate_single_bucket_feasibility() {
        // One candidate, generous SLO: the planner must pick it.
        let p = plan(
            &pcfg(&[8]),
            &model_1_1(),
            &[profile("a", 50.0, Duration::from_secs(1))],
        )
        .unwrap();
        assert!(p.is_feasible());
        let l = &p.lanes[0];
        assert_eq!(l.buckets, vec![8]);
        assert!(l.flush_timeout > Duration::ZERO);
        assert!(l.predicted.p99 <= Duration::from_secs(1));
        l.batcher().unwrap();
        l.lane_spec(64).unwrap();
    }

    #[test]
    fn deadline_infeasible_at_any_bucket_is_reported_not_looped() {
        // service(1) = 2 ms > 0.9 × 2 ms budget: nothing can fit.
        let p = plan(
            &pcfg(&[1, 2, 4, 8]),
            &model_1_1(),
            &[profile("tight", 10.0, ms(2))],
        )
        .unwrap();
        assert!(!p.is_feasible());
        let l = &p.lanes[0];
        assert!(l.buckets.is_empty());
        match &l.verdict {
            PlanVerdict::Infeasible { reason } => {
                assert!(reason.contains("deadline"), "reason: {reason}");
            }
            v => panic!("expected infeasible, got {v:?}"),
        }
        // An infeasible plan refuses to fabricate a batcher.
        assert!(l.batcher().is_err());
    }

    #[test]
    fn capacity_infeasible_is_reported_with_the_rate() {
        // capacity at b=8, 1 worker: 8 / 9 ms ≈ 889 req/s.  Offer 10×.
        let p = plan(
            &pcfg(&[1, 2, 4, 8]),
            &model_1_1(),
            &[profile("hot", 9000.0, ms(100))],
        )
        .unwrap();
        assert!(!p.is_feasible());
        match &p.lanes[0].verdict {
            PlanVerdict::Infeasible { reason } => {
                assert!(reason.contains("capacity"), "reason: {reason}");
            }
            v => panic!("expected infeasible, got {v:?}"),
        }
        assert!(p.lanes[0].predicted.utilization > 1.0);
    }

    #[test]
    fn sparse_interactive_lane_gets_bucket_one() {
        // 20 req/s against an 888 req/s pool: lone requests dominate,
        // so any subset without bucket 1 pays padding — the winner
        // must include 1, and the big bucket for throughput headroom.
        let p = plan(
            &pcfg(&[1, 2, 4, 8]),
            &model_1_1(),
            &[profile("chat", 20.0, ms(12))],
        )
        .unwrap();
        assert!(p.is_feasible());
        let l = &p.lanes[0];
        assert_eq!(l.buckets, vec![1, 8]);
        assert_eq!(l.predicted.padding_fraction, 0.0);
        assert!(l.predicted.p99 <= ms(12));
        // b_min == 1 ⇒ no flush exposure in the p99.
        assert!(l.predicted.p99 >= ms(9), "must include service(8)");
    }

    #[test]
    fn saturated_lane_takes_one_big_bucket() {
        // Back-to-back: padding is zero everywhere, so the score falls
        // through to per-request service cost (b=8 wins) and then to
        // compile count ({8} beats {1,8}).
        let p = plan(
            &pcfg(&[1, 2, 4, 8]),
            &model_1_1(),
            &[profile("bulk", 0.0, Duration::from_secs(1))],
        )
        .unwrap();
        assert!(p.is_feasible());
        assert_eq!(p.lanes[0].buckets, vec![8]);
        assert_eq!(p.lanes[0].predicted.utilization, 0.0);
    }

    #[test]
    fn explicit_size_dist_drives_the_bucket_choice() {
        // All bursts are exactly 3 requests; an 8 ms deadline (7.2 ms
        // budget) admits service(4) = 5 ms plus its p99 queueing wait
        // but rejects service(8) = 9 ms.  Two compiles max: {1,4}
        // clears 3 as 1+1+1 with zero padding and the best
        // per-request cost among pad-free pairs.
        let mut cfg = pcfg(&[1, 2, 4, 8]);
        cfg.max_compiled = 2;
        let mut lane = profile("burst3", 50.0, ms(8));
        lane.size_dist = vec![(3, 1.0)];
        let p = plan(&cfg, &model_1_1(), &[lane]).unwrap();
        assert!(p.is_feasible());
        let l = &p.lanes[0];
        assert_eq!(l.buckets, vec![1, 4]);
        assert_eq!(l.predicted.padding_fraction, 0.0);
        assert!(l.buckets.len() <= 2);
    }

    #[test]
    fn lanes_are_sized_against_their_weight_share_of_the_pool() {
        // Pool capacity at b=8 over 2 workers ≈ 1778 req/s.  Two
        // equal-weight lanes each offering 1200 req/s fit the pool
        // *alone* but overcommit it together: each lane's guaranteed
        // share is ≈ 889 req/s, so both must come back
        // capacity-infeasible — the weighted-deficit scheduler cannot
        // serve either lane past its share under contention.
        let mut cfg = pcfg(&[1, 2, 4, 8]);
        cfg.workers = 2;
        let p = plan(
            &cfg,
            &model_1_1(),
            &[
                profile("a", 1200.0, ms(100)),
                profile("b", 1200.0, ms(100)),
            ],
        )
        .unwrap();
        assert!(!p.is_feasible());
        for l in &p.lanes {
            match &l.verdict {
                PlanVerdict::Infeasible { reason } => {
                    assert!(reason.contains("capacity"), "reason: {reason}");
                }
                v => panic!("expected share infeasibility, got {v:?}"),
            }
            assert!(l.buckets.is_empty());
        }
        // The same rated lane next to a saturated filler passes only
        // when its weight guarantees it enough of the pool: weight
        // 3:1 gives it 75 % ≈ 1333 req/s ≥ 1200 offered.  (Generous
        // deadline — at ρ = 0.9 the p99 queueing wait alone is
        // ≈ 186 ms.)
        let rated = |weight: u64| LaneProfile {
            weight,
            ..profile("a", 1200.0, ms(400))
        };
        let bulk = profile("bulk", 0.0, Duration::from_secs(1));
        let p = plan(&cfg, &model_1_1(), &[rated(1), bulk.clone()]).unwrap();
        assert!(
            !p.lanes[0].is_feasible(),
            "half a pool (889 req/s) cannot absorb 1200 req/s"
        );
        let p = plan(&cfg, &model_1_1(), &[rated(3), bulk]).unwrap();
        assert!(p.is_feasible(), "a 75% share (1333 req/s) absorbs 1200");
        assert!(p.lanes[0].predicted.utilization > 0.8);
    }

    #[test]
    fn infeasible_lane_does_not_poison_its_neighbours() {
        let p = plan(
            &pcfg(&[1, 2, 4, 8]),
            &model_1_1(),
            &[
                profile("ok", 20.0, ms(50)),
                profile("doomed", 10.0, ms(1)),
            ],
        )
        .unwrap();
        assert!(!p.is_feasible());
        assert!(p.lanes[0].is_feasible());
        assert!(!p.lanes[1].is_feasible());
        // The compile work list only carries feasible lanes' buckets.
        assert_eq!(p.all_buckets(), p.lanes[0].buckets);
    }

    #[test]
    fn poisson_sizes_concentrate_where_the_load_says() {
        // Tiny window: essentially all mass at size 1.
        let d = poisson_sizes(0.01, 8);
        assert!(d[0].0 == 1 && d[0].1 > 0.99);
        // Huge window: the tail lump at cap takes everything.
        let d = poisson_sizes(1e6, 8);
        let cap_mass = d.iter().find(|&&(s, _)| s == 8).unwrap().1;
        assert!(cap_mass > 0.99);
        // Always a normalized distribution.
        for lambda in [0.1, 1.0, 4.0, 32.0] {
            let d = poisson_sizes(lambda, 8);
            let total: f64 = d.iter().map(|(_, w)| w).sum();
            assert!((total - 1.0).abs() < 1e-9, "λ={lambda}: Σ={total}");
            assert!(d.iter().all(|&(s, w)| s >= 1 && w >= 0.0));
        }
    }

    #[test]
    fn poisson_sizes_survive_exp_underflow_at_high_lambda() {
        // exp(−λ) underflows to 0 for λ ≳ 746; the old recurrence
        // seeded from it then returned exactly zero for every head
        // size.  At λ = 1000 the head really is negligible (the flush
        // window holds ~1000 arrivals), so the mass must concentrate
        // at the cap — as a normalized distribution over every size,
        // not a degenerate fallback.
        let d = poisson_sizes(1000.0, 8);
        assert_eq!(d.len(), 8);
        let total: f64 = d.iter().map(|(_, w)| w).sum();
        assert!((total - 1.0).abs() < 1e-9, "Σ={total}");
        assert!(d.iter().all(|&(_, w)| w.is_finite() && w >= 0.0));
        let cap_mass = d.iter().find(|&&(s, _)| s == 8).unwrap().1;
        assert!(
            cap_mass > 0.999,
            "λ ≫ cap must concentrate at cap, got {cap_mass}"
        );
        // Just past the underflow cliff the individually-representable
        // head masses survive log space: at λ = 750 the s = 7 mass is
        // ~e^{−712} — tiny but nonzero, where the old recurrence
        // (seeded from exp(−750) = 0) produced exactly 0.
        let d = poisson_sizes(750.0, 8);
        assert_eq!(d[6].0, 7);
        assert!(d[6].1 > 0.0, "head mass at s=7 lost to underflow");
        assert!(d[6].1 < 1e-300, "head mass at s=7 should be negligible");
    }

    #[test]
    fn pow2_candidates_match_discover_ladder() {
        assert_eq!(pow2_candidates(8), vec![1, 2, 4, 8]);
        assert_eq!(pow2_candidates(6), vec![1, 2, 4, 6]);
        assert_eq!(pow2_candidates(1), vec![1]);
        assert!(pow2_candidates(0).is_empty());
    }

    #[test]
    fn planning_is_deterministic() {
        let lanes = [
            profile("a", 35.0, ms(25)),
            profile("b", 0.0, Duration::from_secs(1)),
        ];
        let p1 = plan(&pcfg(&[1, 2, 4, 8]), &model_1_1(), &lanes).unwrap();
        let p2 = plan(&pcfg(&[1, 2, 4, 8]), &model_1_1(), &lanes).unwrap();
        for (a, b) in p1.lanes.iter().zip(&p2.lanes) {
            assert_eq!(a.buckets, b.buckets);
            assert_eq!(a.flush_timeout, b.flush_timeout);
            assert_eq!(a.predicted.p99, b.predicted.p99);
        }
    }
}
