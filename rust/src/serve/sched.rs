//! Continuous-batching multi-lane scheduler + deterministic
//! simulation harness.
//!
//! One [`Scheduler`] multiplexes any number of (model, precision)
//! *lanes* — each a [`RequestQueue`] with its own bucket set, flush
//! timeout, and weight — over one shared worker pool:
//!
//! * **Continuous refill** — a worker that frees a slot immediately
//!   asks [`Scheduler::next_work`] for the next dispatchable bucket
//!   (policy: [`refill`](crate::serve::batcher::refill)); batches are
//!   never formed ahead of a worker that could run them, and workers
//!   never idle while any lane has a fillable bucket.
//! * **Weighted-deficit lane picking** — lanes are served
//!   deficit-round-robin: on each fresh visit a lane banks
//!   `weight × quantum` credit and keeps dispatching while the credit
//!   covers the batch (cost = real requests dispatched), so under
//!   saturation lanes get service in exact proportion to their
//!   weights, and a flushed partial in one lane is never starved by a
//!   saturated neighbour for more than one deficit round.
//! * **Per-request completion callbacks** — [`Scheduler::complete`]
//!   fires the registered [`CompletionFn`] once per admitted request
//!   (streaming responses), replacing batch-granularity completion.
//! * **Autoscaling** — [`Scheduler::poll_autoscale`] compares total
//!   backlog against [`AutoscalePolicy`] and tells the engine to
//!   spawn workers or grants [`Work::Retire`] to drain them.
//!
//! All timing flows through the engine
//! [`Clock`](crate::serve::clock::Clock), so the exact same scheduler
//! state machine runs threaded under [`WallClock`]
//! (production, [`Scheduler::next_work`] blocking on a condvar) and
//! single-threaded under [`VirtualClock`] in [`simulate`] — an
//! event-driven replay with no real sleeps that makes flush timing,
//! deadline misses, fairness, and autoscaling exactly reproducible.
//!
//! The same property makes the scheduler the natural span-recording
//! site ([`crate::trace`]): dispatch stamps
//! [`FormedBatch::dispatched`], and completion records the
//! queue-wait, per-request service, and per-batch execute spans — one
//! instrumentation path shared by the threaded engine and the
//! simulation, so virtual-clock traces are bit-deterministic and
//! `queue_wait + service == observed latency` holds exactly.
//!
//! [`WallClock`]: crate::serve::clock::WallClock
//! [`VirtualClock`]: crate::serve::clock::VirtualClock

use std::collections::BinaryHeap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::metrics::LatencyHistogram;
use crate::serve::batcher::{BatcherConfig, FormedBatch, SchedPolicy};
use crate::serve::calibrate::{ReplanDriver, ReplanSpec};
use crate::serve::clock::{Clock, VirtualClock};
use crate::serve::planner::LaneProfile;
use crate::serve::queue::{QueuePoll, QueueStats, Request, RequestQueue};
use crate::trace::{Span, SpanKind, Tracer};

/// Static description of one (model, precision) lane.
///
/// The bucket set and flush timeout inside `batcher` are *inputs*
/// here: production derives them per lane from the latency-aware
/// planner ([`LanePlan::lane_spec`](crate::serve::planner::LanePlan))
/// when per-lane SLOs are configured, falling back to the static
/// discovered-artifact list otherwise; the scheduler itself only ever
/// dispatches at the sizes this spec names.
#[derive(Debug, Clone)]
pub struct LaneSpec {
    /// Display/routing name, e.g. `"vit_tiny/mixed_f16"`.
    pub name: String,
    /// Deficit-round-robin weight (≥ 1): service share under
    /// saturation is proportional to this.
    pub weight: u64,
    pub batcher: BatcherConfig,
    pub queue_capacity: usize,
    /// Per-request end-to-end budget (reported, not enforced) — the
    /// p99 SLO the planner planned `batcher` against.
    pub deadline: Duration,
}

/// One request's completion, streamed to the registered callback the
/// moment its batch finishes — there is no batch-granularity response.
pub struct Completion<'a> {
    pub lane: usize,
    pub lane_name: &'a str,
    pub worker: usize,
    pub request: &'a Request,
    /// Completion timestamp (clock-epoch offset).
    pub done: Duration,
    pub latency: Duration,
    pub missed_deadline: bool,
    /// This request's slice of the executed batch's output (its
    /// logits row).  Empty when the completing path did not capture
    /// outputs ([`Scheduler::complete`] — the simulation and
    /// benchmark paths); populated by
    /// [`Scheduler::complete_streamed`], which the production worker
    /// loop calls so a network transport can hand each caller its
    /// result the moment the batch finishes.
    pub output: &'a [f32],
}

/// Streaming completion callback.  Fired exactly once per *admitted*
/// request, from the completing worker's thread, outside all
/// scheduler locks.
pub type CompletionFn = dyn Fn(&Completion) + Send + Sync;

/// Worker-pool sizing policy, driven by total queue backlog.
#[derive(Debug, Clone, Copy)]
pub struct AutoscalePolicy {
    pub min_workers: usize,
    pub max_workers: usize,
    /// Backlog one worker is expected to absorb: the pool grows
    /// toward `ceil(depth / depth_per_worker)` workers (clamped).
    pub depth_per_worker: usize,
}

impl AutoscalePolicy {
    /// A fixed pool of exactly `n` workers (autoscaling off).
    pub fn fixed(n: usize) -> AutoscalePolicy {
        AutoscalePolicy {
            min_workers: n,
            max_workers: n,
            depth_per_worker: usize::MAX,
        }
    }

    /// Pool size this policy wants for `depth` queued requests.
    pub fn desired(&self, depth: usize) -> usize {
        let per = self.depth_per_worker.max(1);
        let need = depth.saturating_add(per - 1) / per;
        need.clamp(self.min_workers, self.max_workers)
    }
}

/// What the engine should do about pool size right now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleOp {
    Spawn(usize),
    Retire(usize),
    Hold,
}

/// What [`Scheduler::next_work`] hands a worker.
pub enum Work {
    Batch { lane: usize, batch: FormedBatch },
    /// Autoscale-down: this worker should exit.
    Retire,
    /// Every lane is closed and drained: exit.
    Shutdown,
}

/// Non-blocking poll result (the simulation driver's interface; the
/// blocking [`Scheduler::next_work`] loops over this).
pub enum PollWork {
    Batch { lane: usize, batch: FormedBatch },
    /// A partial batch flushes at this instant; nothing sooner.
    WaitUntil(Duration),
    /// All lanes empty (some may still get arrivals).
    Idle,
    Retire,
    Shutdown,
}

struct Lane {
    spec: LaneSpec,
    queue: RequestQueue,
}

struct SchedState {
    /// Deficit-round-robin credit per lane, in request units.
    credit: Vec<i64>,
    /// Lane the round-robin scan starts at.
    cursor: usize,
    /// Has the cursor lane banked its quantum since the cursor
    /// arrived there?
    topped: bool,
    /// Workers currently executing a batch.
    busy: usize,
    /// Live (spawned − retired/failed) workers.
    live: usize,
    /// Retire grants not yet handed out.
    retiring: usize,
    spawned: usize,
    retired: usize,
    /// Live per-lane dispatch configs.  Seeded from the lane specs
    /// and hot-swapped by [`Scheduler::adopt_plan`]; kept in the
    /// locked state (rather than the immutable specs) precisely so a
    /// live replan can retune bucket sets and flush timeouts without
    /// draining anything.
    batchers: Vec<BatcherConfig>,
    /// DRR quantum: the largest bucket across the *live* batchers, so
    /// one top-up always covers at least one batch.  Recomputed on
    /// every adopted plan.
    quantum: i64,
    /// Plans adopted since startup (`mpx_serve_replans_total`).
    replans: u64,
    /// Per-lane `(overhead_us, per_row_us)` service model behind the
    /// current plan (`mpx_serve_service_model` gauges).
    model: Vec<(u64, u64)>,
}

/// Live/spawned/retired/busy snapshot for reports.
#[derive(Debug, Clone, Copy)]
pub struct PoolCounters {
    pub live: usize,
    pub busy: usize,
    pub spawned: usize,
    pub retired: usize,
}

pub struct Scheduler {
    lanes: Vec<Lane>,
    policy: SchedPolicy,
    autoscale: AutoscalePolicy,
    clock: Arc<dyn Clock>,
    on_complete: Option<Box<CompletionFn>>,
    /// Span recorder ([`crate::trace`]); `None` costs nothing on the
    /// dispatch/complete paths.
    tracer: Option<Arc<Tracer>>,
    state: Mutex<SchedState>,
    /// Woken on arrivals, close, and retire grants.
    work: Condvar,
}

impl Scheduler {
    pub fn new(
        specs: Vec<LaneSpec>,
        policy: SchedPolicy,
        autoscale: AutoscalePolicy,
        clock: Arc<dyn Clock>,
        on_complete: Option<Box<CompletionFn>>,
    ) -> Result<Scheduler> {
        if specs.is_empty() {
            bail!("scheduler: no lanes");
        }
        if autoscale.min_workers == 0
            || autoscale.max_workers < autoscale.min_workers
        {
            bail!(
                "scheduler: bad autoscale bounds [{}, {}]",
                autoscale.min_workers,
                autoscale.max_workers
            );
        }
        let mut quantum = 0i64;
        for s in &specs {
            if s.weight == 0 {
                bail!("scheduler: lane {} has zero weight", s.name);
            }
            s.batcher.validate()?;
            quantum = quantum.max(s.batcher.max_batch() as i64);
        }
        let n = specs.len();
        let batchers: Vec<BatcherConfig> =
            specs.iter().map(|s| s.batcher.clone()).collect();
        let lanes = specs
            .into_iter()
            .map(|spec| Lane {
                queue: RequestQueue::new(spec.queue_capacity, clock.clone()),
                spec,
            })
            .collect();
        Ok(Scheduler {
            lanes,
            policy,
            autoscale,
            clock,
            on_complete,
            tracer: None,
            state: Mutex::new(SchedState {
                credit: vec![0; n],
                cursor: 0,
                topped: false,
                busy: 0,
                live: 0,
                retiring: 0,
                spawned: 0,
                retired: 0,
                batchers,
                quantum,
                replans: 0,
                model: vec![(0, 0); n],
            }),
            work: Condvar::new(),
        })
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane_name(&self, lane: usize) -> &str {
        &self.lanes[lane].spec.name
    }

    pub fn lane_stats(&self, lane: usize) -> QueueStats {
        self.lanes[lane].queue.stats()
    }

    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Attach a span recorder.  Called once during engine setup,
    /// before the scheduler is shared across threads (hence `&mut`).
    pub fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        self.tracer = Some(tracer);
    }

    /// The attached span recorder, if any — worker loops and the
    /// transport instrument their own phases through this.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    pub fn counters(&self) -> PoolCounters {
        let st = self.state.lock().unwrap();
        PoolCounters {
            live: st.live,
            busy: st.busy,
            spawned: st.spawned,
            retired: st.retired,
        }
    }

    /// Total queued (not yet dispatched) requests across lanes.
    pub fn total_depth(&self) -> usize {
        self.lanes.iter().map(|l| l.queue.depth()).sum()
    }

    /// The engine just added `n` workers to the pool.
    pub fn register_workers(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        st.live += n;
        st.spawned += n;
    }

    /// Take the scheduler lock (and release it) before notifying, so
    /// a worker that just decided to wait cannot miss the wakeup.
    fn kick(&self) {
        drop(self.state.lock().unwrap());
        self.work.notify_all();
    }

    /// Same handshake, one waiter: a single arrival can complete at
    /// most one batch, so waking every idle worker (and paying a full
    /// DRR scan per worker per request) would be a thundering herd.
    fn kick_one(&self) {
        drop(self.state.lock().unwrap());
        self.work.notify_one();
    }

    /// Open-loop submission: rejected (and counted in the lane's
    /// stats) when the lane is full, closed, or zero-capacity.
    pub fn submit(&self, lane: usize, req: Request) -> bool {
        let id = req.id;
        let ok = self.lanes[lane].queue.try_enqueue(req);
        if ok {
            self.trace_admit(lane, id);
            self.kick_one();
        }
        ok
    }

    /// Closed-loop submission: blocks for space (backpressure);
    /// returns `false` only on a closed or zero-capacity lane.
    pub fn submit_blocking(&self, lane: usize, req: Request) -> bool {
        let id = req.id;
        let ok = self.lanes[lane].queue.enqueue(req);
        if ok {
            self.trace_admit(lane, id);
            self.kick_one();
        }
        ok
    }

    /// Admission marker — the same clock the queue stamped
    /// `Request::enqueued` with, so the instant matches the
    /// queue-wait span's start exactly.
    fn trace_admit(&self, lane: usize, id: u64) {
        if let Some(t) = &self.tracer {
            t.instant(SpanKind::Admit, self.clock.now(), lane as u64, id, 0);
        }
    }

    /// Stop arrivals on every lane; workers drain and shut down.
    pub fn close_all(&self) {
        for lane in &self.lanes {
            lane.queue.close();
        }
        self.kick();
    }

    pub fn all_closed(&self) -> bool {
        self.lanes.iter().all(|l| l.queue.is_closed())
    }

    /// Whether `lane` stopped admitting (drain or worker failure) —
    /// the transport maps this to `503` rather than `429`.
    pub fn lane_is_closed(&self, lane: usize) -> bool {
        self.lanes[lane].queue.is_closed()
    }

    /// Current queued depth of one lane (reporting/metrics).
    pub fn lane_depth(&self, lane: usize) -> usize {
        self.lanes[lane].queue.depth()
    }

    fn advance(&self, st: &mut SchedState) {
        st.cursor = (st.cursor + 1) % self.lanes.len();
        st.topped = false;
    }

    /// One deficit-round-robin scan over the lanes at `now`.  Must be
    /// called with the state lock held; lock order is always
    /// scheduler-state → lane-queue.
    fn poll_locked(&self, st: &mut SchedState, now: Duration) -> PollWork {
        // Retire grants first, re-checked against the current backlog
        // so a burst that arrived after the grant cancels it.
        if st.retiring > 0 {
            if self.autoscale.desired(self.total_depth()) < st.live {
                st.retiring -= 1;
                st.live -= 1;
                st.retired += 1;
                return PollWork::Retire;
            }
            st.retiring = 0;
        }
        if self.lanes.iter().all(|l| l.queue.is_drained()) {
            return PollWork::Shutdown;
        }
        let n = self.lanes.len();
        let mut wait: Option<Duration> = None;
        // n + 1 visits: if the cursor lane's previous turn left it
        // topped-up but out of credit, the scan wraps around and
        // revisits it fresh (new top-up) instead of reporting Idle
        // with work still queued.
        for _ in 0..=n {
            let i = st.cursor;
            let lane = &self.lanes[i];
            match lane.queue.poll(&st.batchers[i], self.policy, now) {
                QueuePoll::Ready(take) => {
                    if !st.topped {
                        // Fresh visit: bank one quantum of credit.
                        st.credit[i] += lane.spec.weight as i64 * st.quantum;
                        st.topped = true;
                    }
                    if st.credit[i] >= take as i64 {
                        if let Some(mut batch) =
                            lane.queue.pop(&st.batchers[i], take)
                        {
                            // The dispatch instant: trace spans pivot
                            // here (queue-wait ends, service starts).
                            batch.dispatched = now;
                            st.credit[i] -= batch.requests.len() as i64;
                            st.busy += 1;
                            // Cursor sticks: the lane keeps its turn
                            // while credit lasts.
                            return PollWork::Batch { lane: i, batch };
                        }
                    }
                    // Credit spent (or queue emptied underneath a
                    // defensive race): next lane's turn.
                    self.advance(st);
                }
                QueuePoll::WaitUntil(at) => {
                    st.credit[i] = 0;
                    wait = Some(wait.map_or(at, |w| w.min(at)));
                    self.advance(st);
                }
                QueuePoll::Idle => {
                    // Idle lanes bank no credit (classic DRR reset).
                    st.credit[i] = 0;
                    self.advance(st);
                }
                QueuePoll::Drained => {
                    st.credit[i] = 0;
                    self.advance(st);
                }
            }
        }
        match wait {
            Some(at) => PollWork::WaitUntil(at),
            None => PollWork::Idle,
        }
    }

    /// Non-blocking dispatch attempt at `now` — the simulation
    /// driver's entry point.  A returned [`PollWork::Batch`] *must*
    /// be answered later with [`Scheduler::complete`] (or
    /// [`Scheduler::worker_failed`]).
    pub fn poll_work(&self, now: Duration) -> PollWork {
        let mut st = self.state.lock().unwrap();
        self.poll_locked(&mut st, now)
    }

    /// Blocking dispatch: waits on arrivals / flush deadlines /
    /// close.  Production workers loop on this.
    pub fn next_work(&self) -> Work {
        let mut st = self.state.lock().unwrap();
        loop {
            let now = self.clock.now();
            match self.poll_locked(&mut st, now) {
                PollWork::Batch { lane, batch } => {
                    return Work::Batch { lane, batch }
                }
                PollWork::Retire => return Work::Retire,
                PollWork::Shutdown => return Work::Shutdown,
                PollWork::WaitUntil(at) => {
                    let dur = at.saturating_sub(self.clock.now());
                    let (g, _) = self.work.wait_timeout(st, dur).unwrap();
                    st = g;
                }
                PollWork::Idle => {
                    st = self.work.wait(st).unwrap();
                }
            }
        }
    }

    /// A worker finished `batch` at `done`: free its slot and stream
    /// each request's completion to the callback.  Returns the number
    /// of deadline misses in the batch.
    pub fn complete(
        &self,
        worker: usize,
        lane: usize,
        batch: &FormedBatch,
        done: Duration,
    ) -> u64 {
        self.complete_streamed(worker, lane, batch, done, &[])
    }

    /// [`Scheduler::complete`] with the batch's flat output tensor
    /// (`f32[bucket, out_elems]`): each completion carries its own
    /// row as [`Completion::output`], so a streaming callback (the
    /// network transport) can return results per request.  Padding
    /// rows at the tail are ballast and are never surfaced.  An empty
    /// `outputs` (or one whose length is not divisible by the bucket)
    /// degrades to empty per-request slices.
    pub fn complete_streamed(
        &self,
        worker: usize,
        lane: usize,
        batch: &FormedBatch,
        done: Duration,
        outputs: &[f32],
    ) -> u64 {
        {
            let mut st = self.state.lock().unwrap();
            debug_assert!(st.busy > 0, "complete without a dispatch");
            st.busy = st.busy.saturating_sub(1);
        }
        let name = &self.lanes[lane].spec.name;
        let per_row = if outputs.len() % batch.bucket == 0 {
            outputs.len() / batch.bucket
        } else {
            0
        };
        // Trace the batch's timeline around the dispatch anchor
        // stamped in `poll_locked`: one execute span per batch (the
        // planner's calibration signal) and a queue-wait + service
        // pair per request.  `enqueued ≤ dispatched ≤ done` along
        // this path, so the spans tile the observed latency exactly:
        // `queue_wait + service == done − enqueued`.
        if let Some(t) = &self.tracer {
            t.record(
                SpanKind::Execute,
                batch.dispatched,
                done,
                lane as u64,
                batch.bucket as u64,
                batch.requests.len() as u64,
            );
            for r in &batch.requests {
                t.record(
                    SpanKind::QueueWait,
                    r.enqueued,
                    batch.dispatched,
                    lane as u64,
                    r.id,
                    0,
                );
                t.record(
                    SpanKind::Service,
                    batch.dispatched,
                    done,
                    lane as u64,
                    r.id,
                    0,
                );
            }
        }
        let mut misses = 0;
        for (i, r) in batch.requests.iter().enumerate() {
            let missed = r.missed_deadline(done);
            if missed {
                misses += 1;
            }
            if let Some(cb) = &self.on_complete {
                cb(&Completion {
                    lane,
                    lane_name: name,
                    worker,
                    request: r,
                    done,
                    latency: done.saturating_sub(r.enqueued),
                    missed_deadline: missed,
                    output: &outputs[i * per_row..(i + 1) * per_row],
                });
            }
        }
        misses
    }

    /// A worker died mid-batch: free its slot, drop it from the pool.
    /// The engine should [`Scheduler::close_all`] so peers drain what
    /// is queued instead of waiting for arrivals that already landed.
    pub fn worker_failed(&self) {
        let mut st = self.state.lock().unwrap();
        st.busy = st.busy.saturating_sub(1);
        st.live = st.live.saturating_sub(1);
    }

    /// A worker died before taking any batch (executor construction
    /// failed): drop it from the pool without touching `busy`.
    pub fn worker_aborted(&self) {
        let mut st = self.state.lock().unwrap();
        st.live = st.live.saturating_sub(1);
    }

    /// Compare backlog to the autoscale policy.  `Spawn(n)` asks the
    /// engine to add workers (it must `register_workers` them);
    /// `Retire(n)` is delivered to workers through
    /// [`Work::Retire`] grants.  Callers poll this on their arrival
    /// path — the load-generator engine after each paced admission,
    /// and the network transport's reactor on every tick that
    /// admitted at least one request.
    pub fn poll_autoscale(&self) -> ScaleOp {
        let depth = self.total_depth();
        let mut st = self.state.lock().unwrap();
        let desired = self.autoscale.desired(depth);
        if desired > st.live {
            ScaleOp::Spawn(desired - st.live)
        } else if desired < st.live {
            let n = st.live - desired;
            st.retiring = st.retiring.max(n);
            drop(st);
            self.kick();
            ScaleOp::Retire(n)
        } else {
            ScaleOp::Hold
        }
    }

    /// Hot-swap lane dispatch configs from a live replan — drains
    /// nothing.  Queued requests re-bucket on their next dispatch
    /// (the DRR scan reads the live batchers under the state lock);
    /// in-flight batches finish on the artifacts they were formed
    /// for.  `full` is false when the caller fell back to a feasible
    /// subset of the compiled buckets (or kept a lane unchanged for
    /// lack of one) — recorded in the `replan` trace instant so the
    /// timeline says so.  Returns the outcome; the replan counter
    /// advances even when nothing changed (the decision itself is an
    /// observable event).
    pub fn adopt_plan(
        &self,
        updates: &[LaneRetune],
        full: bool,
    ) -> Result<AdoptOutcome> {
        for u in updates {
            if u.lane >= self.lanes.len() {
                bail!(
                    "adopt_plan: lane {} out of range ({} lanes)",
                    u.lane,
                    self.lanes.len()
                );
            }
            u.batcher.validate()?;
        }
        let (ordinal, lanes_changed) = {
            let mut st = self.state.lock().unwrap();
            let mut changed = 0usize;
            for u in updates {
                let cur = &st.batchers[u.lane];
                if cur.buckets != u.batcher.buckets
                    || cur.flush_timeout != u.batcher.flush_timeout
                {
                    changed += 1;
                }
                st.batchers[u.lane] = u.batcher.clone();
                st.model[u.lane] = (u.overhead_us, u.per_row_us);
            }
            st.quantum = st
                .batchers
                .iter()
                .map(|b| b.max_batch() as i64)
                .max()
                .unwrap_or(1);
            st.replans += 1;
            (st.replans, changed)
        };
        if let Some(t) = &self.tracer {
            t.instant(
                SpanKind::Replan,
                self.clock.now(),
                ordinal,
                lanes_changed as u64,
                full as u64,
            );
        }
        // Wake blocked workers: the flush deadlines they were waiting
        // on may have moved with the new configs.
        self.kick();
        Ok(AdoptOutcome { ordinal, lanes_changed, full })
    }

    /// Plans adopted since startup (`mpx_serve_replans_total`).
    pub fn replans(&self) -> u64 {
        self.state.lock().unwrap().replans
    }

    /// Per-lane `(overhead_us, per_row_us)` behind the current plan
    /// (`mpx_serve_service_model` gauges); `(0, 0)` until seeded.
    pub fn lane_models(&self) -> Vec<(u64, u64)> {
        self.state.lock().unwrap().model.clone()
    }

    /// Seed the exported service-model gauges at startup (before any
    /// replan) with the model the initial plan was sized against.
    pub fn set_lane_models(&self, models: &[(u64, u64)]) {
        let mut st = self.state.lock().unwrap();
        for (slot, m) in st.model.iter_mut().zip(models) {
            *slot = *m;
        }
    }

    /// Live flush timeouts, post-replan — the transport's 429
    /// `Retry-After` hints read these instead of a startup snapshot.
    pub fn lane_flush_timeouts(&self) -> Vec<Duration> {
        self.state
            .lock()
            .unwrap()
            .batchers
            .iter()
            .map(|b| b.flush_timeout)
            .collect()
    }

    /// The lane's live bucket set (tests, plan reporting).
    pub fn lane_buckets(&self, lane: usize) -> Vec<usize> {
        self.state.lock().unwrap().batchers[lane].buckets.clone()
    }
}

/// One lane's retune from a live replan ([`Scheduler::adopt_plan`]).
#[derive(Debug, Clone)]
pub struct LaneRetune {
    pub lane: usize,
    /// The new bucket set + flush timeout.
    pub batcher: BatcherConfig,
    /// Service-model parameters the replan was sized with, in µs —
    /// exported as `mpx_serve_service_model` gauges.
    pub overhead_us: u64,
    pub per_row_us: u64,
}

/// What [`Scheduler::adopt_plan`] did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdoptOutcome {
    /// 1-based replan ordinal (the `replan` span's `a` attribute).
    pub ordinal: u64,
    /// Lanes whose bucket set or flush timeout actually changed.
    pub lanes_changed: usize,
    /// False when some lane fell back to a compiled-bucket subset or
    /// kept its old config because the planned buckets don't exist.
    pub full: bool,
}

// ---------------------------------------------------------------------------
// Deterministic virtual-clock simulation
// ---------------------------------------------------------------------------

/// One lane's offered load in a simulation.
#[derive(Debug, Clone)]
pub struct LaneLoad {
    pub spec: LaneSpec,
    /// Arrival offsets from simulation start, ascending (e.g. from
    /// [`crate::serve::loadgen::poisson_offsets`]).
    pub arrivals: Vec<Duration>,
}

/// A full simulated serving scenario: lanes + load + a linear service
/// model (`execute = overhead + per_row × bucket`), replayed on a
/// [`VirtualClock`] with zero real sleeps.
#[derive(Debug, Clone)]
pub struct SimSpec {
    pub lanes: Vec<LaneLoad>,
    pub policy: SchedPolicy,
    pub autoscale: AutoscalePolicy,
    pub exec_overhead: Duration,
    pub exec_per_row: Duration,
    /// Halt the replay at this virtual instant (in-flight work is
    /// discarded); `None` runs to full drain (lanes auto-close after
    /// their last arrival).
    pub stop_at: Option<Duration>,
    /// Record every completion and dispatched batch (tests).
    pub record_detail: bool,
    /// Attach a [`Tracer`] to the replayed scheduler and return its
    /// span snapshot in [`SimReport::spans`].  Traces are
    /// bit-deterministic: same spec, same spans.
    pub trace: bool,
    /// Close the planner loop inside the replay: a
    /// [`ReplanDriver`] observes the scheduler's counters at every
    /// event and, on sustained drift, re-plans and hot-swaps lane
    /// configs through [`Scheduler::adopt_plan`] — same machinery the
    /// production transport polls, driven by the virtual clock.
    pub replan: Option<SimReplan>,
}

/// Live-replan inputs for [`simulate`].
#[derive(Debug, Clone)]
pub struct SimReplan {
    pub spec: ReplanSpec,
    /// Per-lane rates the initial lane configs were planned for —
    /// seeds the drift monitor's baseline.
    pub planned_rates: Vec<f64>,
}

/// One streamed completion, as observed by the simulation's callback.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimCompletion {
    pub lane: usize,
    pub id: u64,
    pub enqueued: Duration,
    pub done: Duration,
    pub missed_deadline: bool,
}

/// One dispatched batch (shape only).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimBatch {
    pub lane: usize,
    pub at: Duration,
    pub take: usize,
    pub bucket: usize,
}

#[derive(Debug, Clone)]
pub struct SimLaneReport {
    pub name: String,
    pub offered: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub deadline_misses: u64,
    pub batches: u64,
    pub padded: u64,
    pub latency: LatencyHistogram,
}

#[derive(Debug, Clone)]
pub struct SimReport {
    /// Virtual time from start to the last completion (or `stop_at`
    /// when the replay was truncated).
    pub wall: Duration,
    /// Summed virtual execute time across workers.
    pub busy: Duration,
    pub spawned: usize,
    pub retired: usize,
    pub peak_workers: usize,
    pub lanes: Vec<SimLaneReport>,
    /// Populated when [`SimSpec::record_detail`] is set.
    pub completions: Vec<SimCompletion>,
    pub batches: Vec<SimBatch>,
    /// Span snapshot, populated when [`SimSpec::trace`] is set —
    /// ordered by `(start, seq)`, virtual-clock offsets.
    pub spans: Vec<Span>,
    /// Spans the tracer's ring dropped (oldest-first overflow); zero
    /// means `spans` is the complete timeline.
    pub trace_dropped: u64,
    /// Virtual instants at which a live replan was adopted
    /// ([`SimSpec::replan`]); exact and deterministic.
    pub replans: Vec<Duration>,
}

impl SimReport {
    pub fn offered(&self) -> u64 {
        self.lanes.iter().map(|l| l.offered).sum()
    }

    pub fn completed(&self) -> u64 {
        self.lanes.iter().map(|l| l.completed).sum()
    }

    pub fn deadline_misses(&self) -> u64 {
        self.lanes.iter().map(|l| l.deadline_misses).sum()
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Mean worker utilisation over `workers` fixed slots.
    pub fn occupancy(&self, workers: usize) -> f64 {
        let denom = self.wall.as_secs_f64() * workers as f64;
        if denom <= 0.0 {
            0.0
        } else {
            self.busy.as_secs_f64() / denom
        }
    }

    /// All-lane latency merge.
    pub fn latency(&self) -> LatencyHistogram {
        let mut h = LatencyHistogram::new();
        for l in &self.lanes {
            h.merge(&l.latency);
        }
        h
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EvKind {
    Arrival { lane: usize, idx: u64 },
    Free { worker: usize },
    Timer,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Ev {
    at: Duration,
    seq: u64,
    kind: EvKind,
}

// Min-ordering by (time, push sequence): ties replay in push order,
// so the whole simulation is a deterministic function of the spec.
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Default)]
struct SimTally {
    completed: u64,
    misses: u64,
    latency: LatencyHistogram,
    completions: Vec<SimCompletion>,
}

/// Replay `spec` event-by-event on a virtual clock.  No threads, no
/// sleeps: every run with the same spec produces the same report,
/// bit for bit.
pub fn simulate(spec: SimSpec) -> Result<SimReport> {
    let clock = Arc::new(VirtualClock::new());
    let record = spec.record_detail;
    let tally: Arc<Mutex<Vec<SimTally>>> = Arc::new(Mutex::new(
        spec.lanes.iter().map(|_| SimTally::default()).collect(),
    ));
    let tally_cb = tally.clone();
    let on_complete: Box<CompletionFn> = Box::new(move |c: &Completion| {
        let mut t = tally_cb.lock().unwrap();
        let t = &mut t[c.lane];
        t.completed += 1;
        if c.missed_deadline {
            t.misses += 1;
        }
        t.latency.record(c.latency);
        if record {
            t.completions.push(SimCompletion {
                lane: c.lane,
                id: c.request.id,
                enqueued: c.request.enqueued,
                done: c.done,
                missed_deadline: c.missed_deadline,
            });
        }
    });

    let mut sched = Scheduler::new(
        spec.lanes.iter().map(|l| l.spec.clone()).collect(),
        spec.policy,
        spec.autoscale,
        clock.clone(),
        Some(on_complete),
    )?;
    // Generous fixed ring: simulated scenarios are finite, and a
    // bounded buffer keeps the sim honest about production behaviour.
    let tracer = spec
        .trace
        .then(|| Arc::new(Tracer::new(clock.clone() as Arc<dyn Clock>, 1 << 16)));
    if let Some(t) = &tracer {
        sched.set_tracer(t.clone());
    }
    let sched = sched;

    // Live replan: the driver watches the same cumulative counters
    // the production reactor polls, stepped at every virtual event.
    let mut driver = spec.replan.as_ref().map(|rp| {
        let profiles: Vec<LaneProfile> = spec
            .lanes
            .iter()
            .enumerate()
            .map(|(i, l)| LaneProfile {
                name: l.spec.name.clone(),
                rate: rp.planned_rates.get(i).copied().unwrap_or(0.0),
                deadline: l.spec.deadline,
                weight: l.spec.weight,
                size_dist: vec![(1, 1.0)],
            })
            .collect();
        ReplanDriver::new(rp.spec.clone(), profiles, Duration::ZERO)
    });
    let mut replans: Vec<Duration> = Vec::new();
    let mut done_total = 0u64;
    let mut missed_total = 0u64;

    // Seed the event heap with every arrival, in lane-major order.
    let mut events = BinaryHeap::new();
    let mut seq = 0u64;
    let mut push = |events: &mut BinaryHeap<Ev>, at, kind| {
        events.push(Ev { at, seq, kind });
        seq += 1;
    };
    let mut pending_arrivals = 0u64;
    for (lane, load) in spec.lanes.iter().enumerate() {
        for (idx, &off) in load.arrivals.iter().enumerate() {
            push(&mut events, off, EvKind::Arrival { lane, idx: idx as u64 });
            pending_arrivals += 1;
        }
    }

    let workers0 = spec.autoscale.min_workers;
    sched.register_workers(workers0);
    // Worker slots: `busy[w]` holds the in-flight batch.  Idle slots
    // live on a LIFO stack for deterministic assignment.
    let mut in_flight: Vec<Option<(usize, FormedBatch)>> =
        (0..workers0).map(|_| None).collect();
    let mut idle: Vec<usize> = (0..workers0).rev().collect();
    let mut live_workers = workers0;
    let mut peak_workers = workers0;
    let mut busy_total = Duration::ZERO;
    let mut last_completion = Duration::ZERO;
    let mut batches: Vec<SimBatch> = Vec::new();
    let mut lane_batches: Vec<(u64, u64)> = vec![(0, 0); spec.lanes.len()];
    let mut timer_scheduled: Option<Duration> = None;
    let mut stopped = false;
    let auto_close = spec.stop_at.is_none();

    while let Some(ev) = events.pop() {
        if let Some(stop) = spec.stop_at {
            if ev.at > stop {
                stopped = true;
                break;
            }
        }
        clock.set(ev.at);
        let now = ev.at;
        match ev.kind {
            EvKind::Arrival { lane, idx } => {
                pending_arrivals -= 1;
                let req = Request::new(
                    idx,
                    Vec::new(),
                    spec.lanes[lane].spec.deadline,
                    now,
                );
                // Open-loop admission; rejections are counted by the
                // lane queue's stats.
                sched.submit(lane, req);
                if auto_close && pending_arrivals == 0 {
                    sched.close_all();
                }
            }
            EvKind::Free { worker } => {
                let (lane, batch) = in_flight[worker]
                    .take()
                    .expect("free event for an idle worker");
                missed_total += sched.complete(worker, lane, &batch, now);
                done_total += batch.requests.len() as u64;
                last_completion = now;
                idle.push(worker);
            }
            EvKind::Timer => {
                timer_scheduled = None;
            }
        }

        // Drift check rides every event, like the production reactor
        // tick; a fired replan hot-swaps the lane configs *before*
        // the dispatch scan below, so queued requests re-bucket at
        // this very instant while in-flight batches finish untouched.
        if let Some(d) = driver.as_mut() {
            if d.due(now) {
                let accepted: Vec<u64> = (0..spec.lanes.len())
                    .map(|i| sched.lane_stats(i).accepted)
                    .collect();
                if let Some(rt) =
                    d.poll(now, &accepted, done_total, missed_total)?
                {
                    sched.adopt_plan(&rt.updates, rt.full)?;
                    replans.push(now);
                }
            }
        }

        // Autoscale: grow the pool on backlog (retire grants are
        // delivered through poll_work below).
        if let ScaleOp::Spawn(k) = sched.poll_autoscale() {
            for _ in 0..k {
                let w = in_flight.len();
                in_flight.push(None);
                idle.push(w);
            }
            sched.register_workers(k);
            live_workers += k;
            peak_workers = peak_workers.max(live_workers);
        }

        // Continuous refill: hand every idle slot the next bucket.
        while let Some(&w) = idle.last() {
            match sched.poll_work(now) {
                PollWork::Batch { lane, batch } => {
                    idle.pop();
                    let service = spec.exec_overhead
                        + spec.exec_per_row * batch.bucket as u32;
                    busy_total += service;
                    lane_batches[lane].0 += 1;
                    lane_batches[lane].1 += batch.padding() as u64;
                    if record {
                        batches.push(SimBatch {
                            lane,
                            at: now,
                            take: batch.requests.len(),
                            bucket: batch.bucket,
                        });
                    }
                    in_flight[w] = Some((lane, batch));
                    push(&mut events, now + service, EvKind::Free { worker: w });
                }
                PollWork::WaitUntil(at) => {
                    // One pending timer is enough; earlier wins.
                    if timer_scheduled.map_or(true, |t| at < t) {
                        push(&mut events, at, EvKind::Timer);
                        timer_scheduled = Some(at);
                    }
                    break;
                }
                PollWork::Retire => {
                    // Retired slots are abandoned (never re-used);
                    // autoscale-up later creates fresh slots.
                    idle.pop();
                    live_workers = live_workers.saturating_sub(1);
                }
                PollWork::Idle | PollWork::Shutdown => break,
            }
        }
    }

    let counters = sched.counters();
    let mut tallies = tally.lock().unwrap();
    let mut lanes = Vec::with_capacity(spec.lanes.len());
    let mut completions = Vec::new();
    for (i, load) in spec.lanes.iter().enumerate() {
        let t = std::mem::take(&mut tallies[i]);
        let qs = sched.lane_stats(i);
        completions.extend(t.completions);
        lanes.push(SimLaneReport {
            name: load.spec.name.clone(),
            offered: load.arrivals.len() as u64,
            accepted: qs.accepted,
            rejected: qs.rejected,
            completed: t.completed,
            deadline_misses: t.misses,
            batches: lane_batches[i].0,
            padded: lane_batches[i].1,
            latency: t.latency,
        });
    }
    // Streamed completions interleave across lanes; restore global
    // completion order for the detail record.
    completions.sort_by_key(|c| (c.done, c.lane, c.id));
    Ok(SimReport {
        wall: if stopped {
            spec.stop_at.unwrap()
        } else {
            last_completion
        },
        busy: busy_total,
        spawned: counters.spawned.saturating_sub(workers0),
        retired: counters.retired,
        peak_workers,
        lanes,
        completions,
        batches,
        spans: tracer
            .as_ref()
            .map(|t| t.snapshot())
            .unwrap_or_default(),
        trace_dropped: tracer.map(|t| t.dropped()).unwrap_or(0),
        replans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn lane(name: &str, weight: u64, buckets: &[usize]) -> LaneSpec {
        LaneSpec {
            name: name.into(),
            weight,
            batcher: BatcherConfig::new(buckets.to_vec(), ms(5)).unwrap(),
            queue_capacity: 4096,
            deadline: Duration::from_secs(10),
        }
    }

    #[test]
    fn autoscale_desired_clamps() {
        let p = AutoscalePolicy {
            min_workers: 2,
            max_workers: 6,
            depth_per_worker: 8,
        };
        assert_eq!(p.desired(0), 2);
        assert_eq!(p.desired(16), 2);
        assert_eq!(p.desired(17), 3);
        assert_eq!(p.desired(48), 6);
        assert_eq!(p.desired(10_000), 6);
        let f = AutoscalePolicy::fixed(3);
        assert_eq!(f.desired(0), 3);
        assert_eq!(f.desired(usize::MAX), 3);
    }

    #[test]
    fn scheduler_rejects_bad_specs() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        assert!(Scheduler::new(
            vec![],
            SchedPolicy::Continuous,
            AutoscalePolicy::fixed(1),
            clock.clone(),
            None,
        )
        .is_err());
        assert!(Scheduler::new(
            vec![lane("a", 0, &[8])],
            SchedPolicy::Continuous,
            AutoscalePolicy::fixed(1),
            clock.clone(),
            None,
        )
        .is_err());
        assert!(Scheduler::new(
            vec![lane("a", 1, &[8])],
            SchedPolicy::Continuous,
            AutoscalePolicy {
                min_workers: 2,
                max_workers: 1,
                depth_per_worker: 1,
            },
            clock,
            None,
        )
        .is_err());
    }

    #[test]
    fn drr_serves_saturated_lanes_by_weight() {
        // Two saturated bucket-8 lanes, weights 2:1, one slot: the
        // dispatch pattern is exactly A, A, B repeating.
        let clock = Arc::new(VirtualClock::new());
        let sched = Scheduler::new(
            vec![lane("a", 2, &[8]), lane("b", 1, &[8])],
            SchedPolicy::Continuous,
            AutoscalePolicy::fixed(1),
            clock.clone(),
            None,
        )
        .unwrap();
        sched.register_workers(1);
        for i in 0..64 {
            sched.submit(0, Request::new(i, vec![], ms(1000), ms(0)));
            sched.submit(1, Request::new(i, vec![], ms(1000), ms(0)));
        }
        let mut picks = Vec::new();
        for _ in 0..9 {
            match sched.poll_work(ms(0)) {
                PollWork::Batch { lane, batch } => {
                    picks.push(lane);
                    sched.complete(0, lane, &batch, ms(1));
                }
                _ => panic!("expected a batch"),
            }
        }
        assert_eq!(picks, vec![0, 0, 1, 0, 0, 1, 0, 0, 1]);
    }

    #[test]
    fn adopt_plan_hot_swaps_buckets_without_draining() {
        let clock = Arc::new(VirtualClock::new());
        let sched = Scheduler::new(
            vec![lane("a", 1, &[4])],
            SchedPolicy::Continuous,
            AutoscalePolicy::fixed(1),
            clock.clone(),
            None,
        )
        .unwrap();
        sched.register_workers(1);
        for i in 0..12 {
            sched.submit(0, Request::new(i, vec![], ms(1000), ms(0)));
        }
        // Dispatch one bucket-4 batch under the old config and leave
        // it in flight across the swap.
        let (first_lane, first_batch) = match sched.poll_work(ms(0)) {
            PollWork::Batch { lane, batch } => {
                assert_eq!(batch.bucket, 4);
                (lane, batch)
            }
            _ => panic!("expected a batch"),
        };
        // Swap to {8} + a new flush while 8 requests are queued.
        let retune = LaneRetune {
            lane: 0,
            batcher: BatcherConfig::new(vec![8], ms(7)).unwrap(),
            overhead_us: 300,
            per_row_us: 120,
        };
        let out = sched.adopt_plan(&[retune], false).unwrap();
        assert_eq!(
            out,
            AdoptOutcome { ordinal: 1, lanes_changed: 1, full: false }
        );
        assert_eq!(sched.replans(), 1);
        assert_eq!(sched.lane_buckets(0), vec![8]);
        assert_eq!(sched.lane_flush_timeouts(), vec![ms(7)]);
        assert_eq!(sched.lane_models(), vec![(300, 120)]);
        // The in-flight batch completes on its old shape…
        sched.complete(0, first_lane, &first_batch, ms(1));
        // …and the queued requests re-bucket at the new size: the 8
        // still queued form one bucket-8 batch — nothing drained,
        // nothing lost.
        match sched.poll_work(ms(1)) {
            PollWork::Batch { batch, .. } => {
                assert_eq!(batch.bucket, 8);
                assert_eq!(batch.requests.len(), 8);
            }
            _ => panic!("expected the re-bucketed batch"),
        }
        // Re-adopting the identical config changes nothing but still
        // counts the decision.
        let same = LaneRetune {
            lane: 0,
            batcher: BatcherConfig::new(vec![8], ms(7)).unwrap(),
            overhead_us: 300,
            per_row_us: 120,
        };
        let out = sched.adopt_plan(&[same], true).unwrap();
        assert_eq!(
            out,
            AdoptOutcome { ordinal: 2, lanes_changed: 0, full: true }
        );
        // Out-of-range lanes are rejected before anything swaps.
        assert!(sched
            .adopt_plan(
                &[LaneRetune {
                    lane: 5,
                    batcher: BatcherConfig::new(vec![1], ms(1)).unwrap(),
                    overhead_us: 0,
                    per_row_us: 1,
                }],
                true,
            )
            .is_err());
    }

    #[test]
    fn simulate_is_deterministic() {
        let mk = || SimSpec {
            lanes: vec![LaneLoad {
                spec: lane("a", 1, &[1, 2, 4, 8]),
                arrivals: crate::serve::loadgen::poisson_offsets(
                    200, 4000.0, 7,
                ),
            }],
            policy: SchedPolicy::Continuous,
            autoscale: AutoscalePolicy::fixed(2),
            exec_overhead: Duration::from_micros(200),
            exec_per_row: Duration::from_micros(100),
            stop_at: None,
            record_detail: true,
            trace: true,
            replan: None,
        };
        let a = simulate(mk()).unwrap();
        let b = simulate(mk()).unwrap();
        assert_eq!(a.completed(), 200);
        assert_eq!(a.wall, b.wall);
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.batches, b.batches);
        // Traces are part of the determinism contract: same spec,
        // bit-identical spans.
        assert!(!a.spans.is_empty());
        assert_eq!(a.spans, b.spans);
        // Every dispatched batch yields exactly one execute span.
        let execs = a
            .spans
            .iter()
            .filter(|s| s.kind == crate::trace::SpanKind::Execute)
            .count();
        assert_eq!(execs as u64, a.lanes[0].batches);
    }

    #[test]
    fn simulate_drains_everything_without_loss() {
        let rep = simulate(SimSpec {
            lanes: vec![LaneLoad {
                spec: lane("a", 1, &[8]),
                arrivals: vec![Duration::ZERO; 37],
            }],
            policy: SchedPolicy::Continuous,
            autoscale: AutoscalePolicy::fixed(2),
            exec_overhead: ms(1),
            exec_per_row: Duration::ZERO,
            stop_at: None,
            record_detail: false,
            trace: false,
            replan: None,
        })
        .unwrap();
        assert_eq!(rep.completed(), 37);
        assert_eq!(rep.lanes[0].rejected, 0);
        // 37 back-to-back into bucket 8 = 4 full + drain chunks.
        assert!(rep.lanes[0].batches >= 5);
        assert!(rep.wall > Duration::ZERO);
    }
}
