//! Close the planner loop: fit the linear service model from
//! *measured* executions, persist it, and replan live when traffic
//! drifts.
//!
//! The planner ([`crate::serve::planner`]) sizes buckets and flush
//! timeouts against `service(b) = overhead + per_row × b`.  Until
//! this module both parameters came from config constants
//! (`[serve.planner] overhead_us`/`per_row_us`), so every feasibility
//! verdict drifted away from reality as traffic and hardware changed.
//! The loop closes in three pieces:
//!
//! 1. **Fit** — [`Calibration::fit`] runs a deterministic
//!    least-squares fit per `(lane, precision)` over the
//!    [`ServiceSample`] records persisted from execute spans
//!    (`service_samples.json`).  Samples are outlier-trimmed per
//!    batch size, a minimum-sample guard keeps thin lanes on the
//!    config model, and the arithmetic is exact `i128` rational with
//!    one final rounding — the same multiset of samples always yields
//!    a bit-identical `calibration.json`, regardless of input order.
//! 2. **Persist** — [`Calibration::read`]/[`Calibration::write`]
//!    round-trip `calibration.json` next to the artifacts through the
//!    crate's own [`Json`]; [`Calibration::merge`] folds a fresh fit
//!    into the existing file per lane key instead of clobbering it.
//!    `[serve.planner] source = "calibrated"` makes
//!    [`plan_for_config`](crate::serve::plan_for_config) prefer these
//!    entries over the config constants, lane by lane.
//! 3. **Replan live** — [`DriftMonitor`] watches the scheduler's
//!    existing counters (windowed EWMA arrival rate per lane,
//!    sustained over-deadline completion pressure).  When drift is
//!    sustained for [`DriftConfig::patience`] windows,
//!    [`ReplanDriver::poll`] re-runs the planner with the calibrated
//!    model and the measured rates and emits the per-lane retunes for
//!    [`Scheduler::adopt_plan`](crate::serve::sched::Scheduler::adopt_plan)
//!    — which swaps bucket sets and flush timeouts under the
//!    scheduler lock without draining anything.  A plan that wants
//!    buckets that were never compiled falls back to the feasible
//!    subset of what exists ([`feasible_buckets`]) and says so
//!    (`full = false`, surfaced in the `replan` trace instant and the
//!    adopt outcome).
//!
//! Everything here is clock-agnostic: the virtual-clock harness
//! drives the same monitor/driver event-by-event
//! (`rust/tests/serve_sim.rs` proves a rate step triggers a replan at
//! an exact virtual instant), and the network transport's reactor
//! polls it on its tick.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::metrics::Ema;
use crate::serve::batcher::BatcherConfig;
use crate::serve::planner::{self, LaneProfile, PlannerConfig, ServiceModel};
use crate::serve::sched::LaneRetune;
use crate::trace::ServiceSample;
use crate::util::json::Json;

/// File name of the persisted fit, written next to the artifacts
/// (same directory as `service_samples.json`).
pub const CALIBRATION_FILE: &str = "calibration.json";

/// Minimum post-trim samples a `(lane, precision)` key needs before
/// the fit trusts it; thinner lanes keep the config model.
pub const MIN_FIT_SAMPLES: usize = 8;

/// Outlier trim: within each batch size, the highest and lowest
/// `n / TRIM_DIV` measurements are dropped before fitting (straggler
/// executions — page faults, clock contention — sit far above the
/// linear model and would drag the slope).
const TRIM_DIV: usize = 10;

/// Rounding division for exact rational fits: `num / den` to the
/// nearest integer, half away from zero.  `den` must be positive.
fn round_div(num: i128, den: i128) -> i128 {
    debug_assert!(den > 0);
    if num >= 0 {
        (num + den / 2) / den
    } else {
        -((-num + den / 2) / den)
    }
}

/// One lane's fitted service model, in integer microseconds (integers
/// keep [`Json::dump`] byte-stable and the fit bit-deterministic).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneFit {
    /// Lane *name* (e.g. `"vit_tiny/chat"`) — stable across runs,
    /// unlike the run-local lane index.
    pub lane: String,
    /// Precision tag (`"fp32"`, `"mixed_f16"`, `"mixed_bf16"`): fp32
    /// and half-precision lanes have genuinely different `per_row`
    /// costs, so the key must separate them.
    pub precision: String,
    pub overhead_us: u64,
    pub per_row_us: u64,
    /// Measurements the fit used (after trimming).
    pub samples: u64,
}

impl LaneFit {
    /// The planner-facing model this fit prescribes.
    pub fn model(&self) -> ServiceModel {
        ServiceModel {
            overhead: Duration::from_micros(self.overhead_us),
            per_row: Duration::from_micros(self.per_row_us),
        }
    }
}

/// A set of per-lane fits, ascending by `(lane, precision)` — the
/// in-memory form of `calibration.json`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Calibration {
    pub lanes: Vec<LaneFit>,
}

impl Calibration {
    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    pub fn get(&self, lane: &str, precision: &str) -> Option<&LaneFit> {
        self.lanes
            .iter()
            .find(|f| f.lane == lane && f.precision == precision)
    }

    /// Deterministic least-squares fit of `(overhead, per_row)` per
    /// `(lane, precision)` key.  Order-independent: samples are
    /// grouped and sorted before any arithmetic, sums are exact
    /// `i128`, and rounding happens once at the end — the same
    /// multiset of samples always produces the same `Calibration`,
    /// bit for bit.  Keys with fewer than [`MIN_FIT_SAMPLES`]
    /// post-trim measurements, or with a single distinct batch size
    /// (slope unidentifiable), are omitted.
    pub fn fit(samples: &[ServiceSample]) -> Calibration {
        let mut by_key: BTreeMap<(&str, &str), Vec<(u64, u64)>> =
            BTreeMap::new();
        for s in samples {
            by_key
                .entry(s.lane_key())
                .or_default()
                .push((s.batch_rows as u64, s.exec_us));
        }
        let mut lanes = Vec::new();
        for ((lane, precision), points) in by_key {
            if let Some((overhead_us, per_row_us, used)) =
                fit_points(points)
            {
                lanes.push(LaneFit {
                    lane: lane.to_string(),
                    precision: precision.to_string(),
                    overhead_us,
                    per_row_us,
                    samples: used,
                });
            }
        }
        Calibration { lanes }
    }

    /// Fold `newer` into `self`: entries sharing a `(lane,
    /// precision)` key are replaced by the newer fit, entries only in
    /// `self` survive — a short run refines the lanes it exercised
    /// without clobbering the rest of the calibration history.
    pub fn merge(self, newer: Calibration) -> Calibration {
        let mut map: BTreeMap<(String, String), LaneFit> = self
            .lanes
            .into_iter()
            .map(|f| ((f.lane.clone(), f.precision.clone()), f))
            .collect();
        for f in newer.lanes {
            map.insert((f.lane.clone(), f.precision.clone()), f);
        }
        Calibration {
            lanes: map.into_values().collect(),
        }
    }

    /// `{"lanes": [{"lane", "precision", "overhead_us", "per_row_us",
    /// "samples"}, ...]}` — all values integers, so [`Json::dump`] is
    /// byte-stable.
    pub fn to_json(&self) -> Json {
        let lanes = self
            .lanes
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert("lane".to_string(), Json::Str(f.lane.clone()));
                m.insert(
                    "precision".to_string(),
                    Json::Str(f.precision.clone()),
                );
                m.insert(
                    "overhead_us".to_string(),
                    Json::Num(f.overhead_us as f64),
                );
                m.insert(
                    "per_row_us".to_string(),
                    Json::Num(f.per_row_us as f64),
                );
                m.insert("samples".to_string(), Json::Num(f.samples as f64));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("lanes".to_string(), Json::Arr(lanes));
        Json::Obj(root)
    }

    /// Inverse of [`Calibration::to_json`]; malformed entries are
    /// skipped rather than failing the whole document.
    pub fn parse(doc: &Json) -> Calibration {
        let mut lanes = Vec::new();
        if let Some(arr) = doc.get("lanes").and_then(Json::as_arr) {
            for e in arr {
                let lane = e.get("lane").and_then(Json::as_str);
                let precision = e.get("precision").and_then(Json::as_str);
                let overhead = e.get("overhead_us").and_then(Json::as_i64);
                let per_row = e.get("per_row_us").and_then(Json::as_i64);
                let samples = e.get("samples").and_then(Json::as_i64);
                if let (
                    Some(lane),
                    Some(precision),
                    Some(o),
                    Some(p),
                    Some(n),
                ) = (lane, precision, overhead, per_row, samples)
                {
                    if o >= 0 && p >= 0 && n >= 0 {
                        lanes.push(LaneFit {
                            lane: lane.to_string(),
                            precision: precision.to_string(),
                            overhead_us: o as u64,
                            per_row_us: p as u64,
                            samples: n as u64,
                        });
                    }
                }
            }
        }
        lanes.sort_by(|a, b| {
            (&a.lane, &a.precision).cmp(&(&b.lane, &b.precision))
        });
        Calibration { lanes }
    }

    /// Read `path`; a missing file is an empty calibration (first
    /// run), a present-but-corrupt one is an error.
    pub fn read(path: &Path) -> Result<Calibration> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Calibration::default())
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("read calibration {}", path.display())
                })
            }
        };
        let doc = Json::parse(&text).with_context(|| {
            format!("parse calibration {}", path.display())
        })?;
        Ok(Calibration::parse(&doc))
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json().dump() + "\n").with_context(
            || format!("write calibration {}", path.display()),
        )
    }
}

/// Trim-and-fit one key's points.  Returns `(overhead_us, per_row_us,
/// samples_used)` or `None` under the minimum-sample /
/// identifiability guards.
fn fit_points(points: Vec<(u64, u64)>) -> Option<(u64, u64, u64)> {
    // Group by batch size; sort within the group so trimming is a
    // function of the multiset, not of arrival order.
    let mut by_rows: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    for (rows, us) in points {
        by_rows.entry(rows).or_default().push(us);
    }
    let mut kept: Vec<(u64, u64)> = Vec::new();
    for (rows, mut durs) in by_rows {
        durs.sort_unstable();
        let k = durs.len() / TRIM_DIV;
        for &us in &durs[k..durs.len() - k] {
            kept.push((rows, us));
        }
    }
    if kept.len() < MIN_FIT_SAMPLES {
        return None;
    }
    let first = kept[0].0;
    if kept.iter().all(|&(r, _)| r == first) {
        // One distinct batch size cannot identify both parameters.
        return None;
    }
    let n = kept.len() as i128;
    let mut sx = 0i128;
    let mut sy = 0i128;
    let mut sxy = 0i128;
    let mut sxx = 0i128;
    for &(rows, us) in &kept {
        let x = rows as i128;
        let y = us as i128;
        sx += x;
        sy += y;
        sxy += x * y;
        sxx += x * x;
    }
    let den = n * sxx - sx * sx;
    if den <= 0 {
        return None;
    }
    let s_num = n * sxy - sx * sy;
    // slope = s_num / den; intercept = (sy·den − s_num·sx) / (n·den).
    // A fitted slope below 1 µs/row (or a negative intercept) is
    // clamped into the range the config layer accepts.
    let per_row = round_div(s_num, den).max(1);
    let overhead = round_div(sy * den - s_num * sx, n * den).max(0);
    Some((overhead as u64, per_row as u64, kept.len() as u64))
}

// ---------------------------------------------------------------------------
// Drift detection
// ---------------------------------------------------------------------------

/// Drift-detection knobs.  All comparisons are deterministic given
/// the observation sequence, so the virtual-clock harness can assert
/// the exact replan instant.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// Minimum measurement window; counters are sampled and rates
    /// re-estimated the first observation at or past the boundary.
    pub window: Duration,
    /// EWMA smoothing across windows (1.0 = trust only the latest).
    pub alpha: f64,
    /// A lane breaches when its EWMA arrival rate exceeds
    /// `planned_rate × rate_ratio`.
    pub rate_ratio: f64,
    /// The pool breaches when more than this fraction of a window's
    /// completions missed their deadline (p99 budget ⇒ 0.01 is the
    /// natural setting; higher tolerates bursts).
    pub miss_ratio: f64,
    /// Consecutive breached windows required before firing.
    pub patience: u32,
    /// Minimum spacing between replans.
    pub cooldown: Duration,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig {
            window: Duration::from_secs(1),
            alpha: 0.5,
            rate_ratio: 1.5,
            miss_ratio: 0.05,
            patience: 3,
            cooldown: Duration::from_secs(30),
        }
    }
}

/// What a fired [`DriftMonitor`] measured.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftVerdict {
    /// EWMA arrival rate per lane, req/s.
    pub rates: Vec<f64>,
    /// Human-readable trigger (first breaching condition).
    pub reason: String,
}

/// Watches the scheduler's cumulative counters for sustained drift
/// from the planned load.  Pure state machine: feed it monotonic
/// `(now, accepted-per-lane, completed, missed)` snapshots and it
/// fires a [`DriftVerdict`] after [`DriftConfig::patience`]
/// consecutive breached windows (subject to the cooldown).
#[derive(Debug)]
pub struct DriftMonitor {
    cfg: DriftConfig,
    /// Rates the current plan was sized for; updated on
    /// [`DriftMonitor::note_replan`] so one replan does not re-arm.
    planned: Vec<f64>,
    ema: Vec<Ema>,
    window_start: Duration,
    last_accepted: Vec<u64>,
    last_completed: u64,
    last_missed: u64,
    breaches: u32,
    cooldown_until: Duration,
}

impl DriftMonitor {
    pub fn new(
        cfg: DriftConfig,
        planned_rates: Vec<f64>,
        now: Duration,
    ) -> DriftMonitor {
        let n = planned_rates.len();
        DriftMonitor {
            cfg,
            planned: planned_rates,
            ema: (0..n).map(|_| Ema::new(cfg.alpha)).collect(),
            window_start: now,
            last_accepted: vec![0; n],
            last_completed: 0,
            last_missed: 0,
            breaches: 0,
            cooldown_until: Duration::ZERO,
        }
    }

    /// True when the next [`DriftMonitor::observe`] call would close
    /// a window — lets callers skip gathering counters off-boundary.
    pub fn due(&self, now: Duration) -> bool {
        now >= self.window_start + self.cfg.window
    }

    /// Feed one cumulative-counter snapshot.  Off-boundary snapshots
    /// are free no-ops; at (or past) a window boundary the per-lane
    /// rates are re-estimated over the *actual* elapsed time and the
    /// breach state advances.  Fires at most once per window.
    pub fn observe(
        &mut self,
        now: Duration,
        accepted: &[u64],
        completed: u64,
        missed: u64,
    ) -> Option<DriftVerdict> {
        if !self.due(now) {
            return None;
        }
        let secs = (now - self.window_start).as_secs_f64();
        self.window_start = now;
        let mut rates = Vec::with_capacity(self.planned.len());
        let mut breach: Option<String> = None;
        for i in 0..self.planned.len() {
            let cur = accepted.get(i).copied().unwrap_or(0);
            let delta = cur.saturating_sub(self.last_accepted[i]);
            self.last_accepted[i] = cur;
            let rate = self.ema[i].push(delta as f64 / secs);
            rates.push(rate);
            // Zero/negative planned rate marks a back-to-back lane:
            // throughput-planned, never rate-breaching.
            if breach.is_none()
                && self.planned[i] > 0.0
                && rate > self.planned[i] * self.cfg.rate_ratio
            {
                breach = Some(format!(
                    "lane {i}: measured {rate:.1} req/s vs planned \
                     {:.1} req/s",
                    self.planned[i]
                ));
            }
        }
        let dc = completed.saturating_sub(self.last_completed);
        let dm = missed.saturating_sub(self.last_missed);
        self.last_completed = completed;
        self.last_missed = missed;
        if breach.is_none()
            && dc > 0
            && dm as f64 / dc as f64 > self.cfg.miss_ratio
        {
            breach = Some(format!(
                "{dm}/{dc} completions in the window missed their deadline"
            ));
        }
        match breach {
            Some(reason) => {
                self.breaches += 1;
                if self.breaches >= self.cfg.patience
                    && now >= self.cooldown_until
                {
                    return Some(DriftVerdict { rates, reason });
                }
            }
            None => self.breaches = 0,
        }
        None
    }

    /// The caller adopted a plan sized for `rates`: re-anchor the
    /// planned rates, reset the breach streak, start the cooldown.
    pub fn note_replan(&mut self, now: Duration, rates: &[f64]) {
        for (p, &r) in self.planned.iter_mut().zip(rates) {
            if *p > 0.0 && r > 0.0 {
                *p = r;
            }
        }
        self.breaches = 0;
        self.cooldown_until = now + self.cfg.cooldown;
    }
}

// ---------------------------------------------------------------------------
// Replanning
// ---------------------------------------------------------------------------

/// Intersect the planner's wish list with what is actually compiled.
/// Returns the adoptable subset (ascending, possibly empty) and
/// whether the plan was fully covered.
pub fn feasible_buckets(
    planned: &[usize],
    compiled: &[usize],
) -> (Vec<usize>, bool) {
    let got: Vec<usize> = planned
        .iter()
        .copied()
        .filter(|b| compiled.contains(b))
        .collect();
    let full = got.len() == planned.len();
    (got, full)
}

/// Static inputs of the live-replan loop, cloneable into the
/// simulation spec.
#[derive(Debug, Clone)]
pub struct ReplanSpec {
    pub drift: DriftConfig,
    pub planner: PlannerConfig,
    /// Per-lane service models (calibrated where available).
    pub models: Vec<ServiceModel>,
    /// Per-lane compiled bucket sets — the hard constraint a live
    /// replan cannot plan past: planned buckets outside this set fall
    /// back to the feasible subset.
    pub compiled: Vec<Vec<usize>>,
}

/// The retunes a fired replan wants adopted.
#[derive(Debug, Clone)]
pub struct Retunes {
    /// Per-lane updates for
    /// [`Scheduler::adopt_plan`](crate::serve::sched::Scheduler::adopt_plan);
    /// lanes with no feasible plan (or no compiled overlap) keep
    /// their current config and are absent here.
    pub updates: Vec<LaneRetune>,
    /// False when any lane fell back to a compiled subset or kept its
    /// old config for lack of one.
    pub full: bool,
    /// Measured rates the new plan was sized for.
    pub rates: Vec<f64>,
    pub reason: String,
}

/// Drift monitor + planner + compiled-bucket constraint, bundled for
/// the two call sites (the transport reactor tick and the
/// virtual-clock simulation loop).
#[derive(Debug)]
pub struct ReplanDriver {
    monitor: DriftMonitor,
    spec: ReplanSpec,
    /// Profile template; `rate` is overwritten with the measured EWMA
    /// at each replan.
    profiles: Vec<LaneProfile>,
}

impl ReplanDriver {
    /// `profiles` carry the *planned* rates (seeding the monitor) and
    /// the per-lane names/deadlines/weights/size distributions reused
    /// at replan time.
    pub fn new(
        spec: ReplanSpec,
        profiles: Vec<LaneProfile>,
        now: Duration,
    ) -> ReplanDriver {
        let planned = profiles.iter().map(|p| p.rate).collect();
        ReplanDriver {
            monitor: DriftMonitor::new(spec.drift, planned, now),
            spec,
            profiles,
        }
    }

    /// Cheap boundary test; gather counters only when this is true.
    pub fn due(&self, now: Duration) -> bool {
        self.monitor.due(now)
    }

    /// Feed counters; on sustained drift, re-plan with the calibrated
    /// models at the measured rates and return the retunes (the
    /// caller adopts them via `Scheduler::adopt_plan`).  The monitor
    /// re-anchors on the returned rates, so a successful replan does
    /// not immediately re-arm.
    pub fn poll(
        &mut self,
        now: Duration,
        accepted: &[u64],
        completed: u64,
        missed: u64,
    ) -> Result<Option<Retunes>> {
        let Some(verdict) =
            self.monitor.observe(now, accepted, completed, missed)
        else {
            return Ok(None);
        };
        let mut profiles = self.profiles.clone();
        for (p, &r) in profiles.iter_mut().zip(&verdict.rates) {
            if p.rate > 0.0 && r > 0.0 {
                p.rate = r;
            }
        }
        let plan = planner::plan_with_models(
            &self.spec.planner,
            &self.spec.models,
            &profiles,
        )?;
        let mut updates = Vec::new();
        let mut full = true;
        for (i, lp) in plan.lanes.iter().enumerate() {
            if !lp.is_feasible() {
                full = false;
                continue;
            }
            let (buckets, covered) =
                feasible_buckets(&lp.buckets, &self.spec.compiled[i]);
            if !covered {
                full = false;
            }
            if buckets.is_empty() {
                continue;
            }
            let m = self.spec.models[i];
            updates.push(LaneRetune {
                lane: i,
                batcher: BatcherConfig::new(buckets, lp.flush_timeout)?,
                overhead_us: m.overhead.as_micros() as u64,
                per_row_us: m.per_row.as_micros() as u64,
            });
        }
        self.monitor.note_replan(now, &verdict.rates);
        Ok(Some(Retunes {
            updates,
            full,
            rates: verdict.rates,
            reason: verdict.reason,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(
        lane: &str,
        precision: &str,
        rows: usize,
        us: u64,
    ) -> ServiceSample {
        ServiceSample {
            lane: lane.to_string(),
            precision: precision.to_string(),
            batch_rows: rows,
            exec_us: us,
        }
    }

    /// 12 exact samples on `4000 + 500·rows` across four batch sizes.
    fn linear_samples() -> Vec<ServiceSample> {
        let mut out = Vec::new();
        for &rows in &[1usize, 2, 4, 8] {
            for _ in 0..3 {
                out.push(sample(
                    "m/a",
                    "fp32",
                    rows,
                    4000 + 500 * rows as u64,
                ));
            }
        }
        out
    }

    #[test]
    fn fit_recovers_an_exact_linear_model() {
        let cal = Calibration::fit(&linear_samples());
        assert_eq!(cal.lanes.len(), 1);
        let f = &cal.lanes[0];
        assert_eq!((f.lane.as_str(), f.precision.as_str()), ("m/a", "fp32"));
        assert_eq!(f.overhead_us, 4000);
        assert_eq!(f.per_row_us, 500);
        assert_eq!(f.samples, 12);
        assert_eq!(f.model().service(8), Duration::from_micros(8000));
    }

    #[test]
    fn fit_is_bit_deterministic_and_order_independent() {
        let fwd = linear_samples();
        let mut rev = fwd.clone();
        rev.reverse();
        let a = Calibration::fit(&fwd).to_json().dump();
        let b = Calibration::fit(&fwd).to_json().dump();
        let c = Calibration::fit(&rev).to_json().dump();
        assert_eq!(a, b);
        assert_eq!(a, c);
        // Integer values serialize without a fractional part.
        assert!(a.contains("\"overhead_us\":4000"));
        assert!(a.contains("\"per_row_us\":500"));
    }

    #[test]
    fn fit_trims_straggler_outliers() {
        let mut samples = linear_samples();
        // Ten more clean size-4 measurements plus one straggler: the
        // size-4 group has 14 entries, so the trim (14/10 = 1 from
        // each end) drops the straggler and the group minimum.
        for _ in 0..10 {
            samples.push(sample("m/a", "fp32", 4, 6000));
        }
        samples.push(sample("m/a", "fp32", 4, 1_000_000));
        let cal = Calibration::fit(&samples);
        let f = cal.get("m/a", "fp32").unwrap();
        assert_eq!(f.overhead_us, 4000);
        assert_eq!(f.per_row_us, 500);
        // 23 size-4 + trimmed elsewhere: groups 1,2,8 keep 3 each
        // (3/10 = 0 trimmed), size-4 keeps 12 of 14.
        assert_eq!(f.samples, 21);
    }

    #[test]
    fn fit_guards_thin_and_degenerate_lanes() {
        // Seven samples: below the minimum.
        let thin: Vec<ServiceSample> =
            linear_samples().into_iter().take(7).collect();
        assert!(Calibration::fit(&thin).is_empty());
        // Eight samples, one batch size: slope unidentifiable.
        let flat: Vec<ServiceSample> =
            (0..8).map(|_| sample("m/a", "fp32", 4, 6000)).collect();
        assert!(Calibration::fit(&flat).is_empty());
        // Mixed: the good lane fits, the thin one is omitted.
        let mut mixed = linear_samples();
        mixed.push(sample("m/b", "mixed_f16", 1, 900));
        let cal = Calibration::fit(&mixed);
        assert_eq!(cal.lanes.len(), 1);
        assert!(cal.get("m/b", "mixed_f16").is_none());
    }

    #[test]
    fn merge_replaces_matching_keys_and_keeps_the_rest() {
        let old = Calibration {
            lanes: vec![
                LaneFit {
                    lane: "m/a".into(),
                    precision: "fp32".into(),
                    overhead_us: 100,
                    per_row_us: 10,
                    samples: 50,
                },
                LaneFit {
                    lane: "m/b".into(),
                    precision: "mixed_f16".into(),
                    overhead_us: 200,
                    per_row_us: 20,
                    samples: 60,
                },
            ],
        };
        let newer = Calibration {
            lanes: vec![LaneFit {
                lane: "m/a".into(),
                precision: "fp32".into(),
                overhead_us: 111,
                per_row_us: 11,
                samples: 12,
            }],
        };
        let merged = old.merge(newer);
        assert_eq!(merged.lanes.len(), 2);
        assert_eq!(merged.get("m/a", "fp32").unwrap().overhead_us, 111);
        assert_eq!(merged.get("m/b", "mixed_f16").unwrap().overhead_us, 200);
        // Output stays sorted by key.
        assert!(merged.lanes[0].lane <= merged.lanes[1].lane);
    }

    #[test]
    fn calibration_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join(format!(
            "mpx_cal_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = dir.join(CALIBRATION_FILE);
        // Missing file reads as empty.
        assert!(Calibration::read(&path).unwrap().is_empty());
        let cal = Calibration::fit(&linear_samples());
        cal.write(&path).unwrap();
        let back = Calibration::read(&path).unwrap();
        assert_eq!(back, cal);
        // Corrupt file is an error, not silently empty.
        std::fs::write(&path, "not json").unwrap();
        assert!(Calibration::read(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drift_monitor_fires_after_sustained_breach_only() {
        let cfg = DriftConfig {
            window: Duration::from_millis(500),
            alpha: 0.5,
            rate_ratio: 2.0,
            miss_ratio: 2.0, // pressure path disabled
            patience: 2,
            cooldown: Duration::from_secs(10),
        };
        let mut mon = DriftMonitor::new(cfg, vec![100.0], Duration::ZERO);
        let w = |k: u64| Duration::from_millis(500 * k);
        // Two on-plan windows: 50 accepted per 500 ms ⇒ 100 req/s.
        assert_eq!(mon.observe(w(1), &[50], 0, 0), None);
        assert_eq!(mon.observe(w(2), &[100], 0, 0), None);
        // Rate step to 500 req/s: first breached window arms…
        assert_eq!(mon.observe(w(3), &[350], 0, 0), None);
        // …second fires (patience 2): EWMA = 0.5·300 + 0.5·500 = 400.
        let v = mon.observe(w(4), &[600], 0, 0).unwrap();
        assert_eq!(v.rates, vec![400.0]);
        assert!(v.reason.contains("lane 0"));
        // Re-anchoring on the measured rate absorbs the new level:
        // the same traffic no longer reads as drift.
        mon.note_replan(w(4), &v.rates);
        assert_eq!(mon.observe(w(5), &[850], 0, 0), None);
        // Off-boundary observations are no-ops.
        assert!(!mon.due(w(5) + Duration::from_millis(100)));
    }

    #[test]
    fn drift_monitor_miss_pressure_and_reset() {
        let cfg = DriftConfig {
            window: Duration::from_millis(500),
            alpha: 1.0,
            rate_ratio: 100.0, // rate path disabled
            miss_ratio: 0.01,
            patience: 2,
            cooldown: Duration::ZERO,
        };
        let mut mon = DriftMonitor::new(cfg, vec![100.0], Duration::ZERO);
        let w = |k: u64| Duration::from_millis(500 * k);
        // 5 % of completions late: breach 1 of 2.
        assert_eq!(mon.observe(w(1), &[50], 100, 5), None);
        // A clean window resets the streak…
        assert_eq!(mon.observe(w(2), &[100], 200, 5), None);
        assert_eq!(mon.observe(w(3), &[150], 300, 10), None);
        // …so pressure must be *sustained* to fire.
        let v = mon.observe(w(4), &[200], 400, 20).unwrap();
        assert!(v.reason.contains("missed their deadline"));
    }

    #[test]
    fn feasible_buckets_intersects_and_reports() {
        assert_eq!(
            feasible_buckets(&[1, 8], &[1, 2, 4, 8]),
            (vec![1, 8], true)
        );
        assert_eq!(feasible_buckets(&[1, 8], &[2, 8]), (vec![8], false));
        assert_eq!(feasible_buckets(&[4], &[1, 2]), (vec![], false));
        assert_eq!(feasible_buckets(&[], &[1]), (vec![], true));
    }
}
