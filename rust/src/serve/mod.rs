//! `mpx::serve` — batched-inference serving engine.
//!
//! Inference is where mixed precision pays off with no loss-scaling
//! caveats at all (paper §3): the f16/bf16 forward artifacts can be
//! driven straight at traffic.  This subsystem turns the AOT forward
//! artifacts into a measurable throughput/latency story:
//!
//! ```text
//!   loadgen (deterministic Poisson arrivals, open or closed loop)
//!      │ admission control (bounded queue; reject or backpressure)
//!      ▼
//!   RequestQueue ── next_batch: size-bucketed dynamic batching,
//!      │            padding-aware, flush-on-timeout
//!      ▼
//!   worker pool (N threads, shared compiled executables, per-worker
//!      │         parameter replicas — ddp-style replication)
//!      ▼
//!   per-worker LatencyHistogram ── merge ──► ServeReport
//!                                            (p50/p95/p99, rank-
//!                                             interpolated)
//! ```
//!
//! Module layout:
//!
//! * [`queue`] — bounded MPMC request queue + admission control; owns
//!   the batching wait loop.
//! * [`batcher`] — the pure batching policy (size buckets, padding,
//!   flush-on-timeout) and [`FormedBatch`].
//! * [`worker`] — [`BatchExecutor`] trait, the worker loop, and the
//!   PJRT-artifact executor.
//! * [`loadgen`] — deterministic Poisson arrival schedules.
//!
//! Entry points: [`run`] (any executor — tests use a fake) and
//! [`run_with_artifacts`] (the real PJRT path `mpx serve` drives).

pub mod batcher;
pub mod loadgen;
pub mod queue;
pub mod worker;

pub use batcher::{decide, BatcherConfig, Decision, FormedBatch};
pub use queue::{QueueStats, Request, RequestQueue};
pub use worker::{ArtifactExecutor, BatchExecutor, WorkerReport};

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::{model_preset, ServeConfig};
use crate::data::SyntheticDataset;
use crate::metrics::LatencyHistogram;
use crate::runtime::ArtifactStore;
use crate::util::human_duration;
use worker::worker_loop;

/// Aggregate result of one serving run.
#[derive(Debug)]
pub struct ServeReport {
    /// Wall clock from generator start to full drain.
    pub wall: Duration,
    /// Requests the load generator offered (accepted + rejected).
    pub offered: u64,
    pub queue: QueueStats,
    /// All workers' latencies merged (real requests only).
    pub latency: LatencyHistogram,
    pub workers: Vec<WorkerReport>,
}

impl ServeReport {
    pub fn completed(&self) -> u64 {
        self.latency.count() as u64
    }

    pub fn batches(&self) -> u64 {
        self.workers.iter().map(|w| w.batches).sum()
    }

    pub fn padded(&self) -> u64 {
        self.workers.iter().map(|w| w.padded).sum()
    }

    pub fn deadline_misses(&self) -> u64 {
        self.workers.iter().map(|w| w.deadline_misses).sum()
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Share of executed rows that were padding ballast.
    pub fn padding_fraction(&self) -> f64 {
        let real = self.completed();
        let pad = self.padded();
        if real + pad == 0 {
            0.0
        } else {
            pad as f64 / (real + pad) as f64
        }
    }

    /// Human-readable run summary on stdout.
    pub fn print(&self, label: &str) {
        println!(
            "[serve] {label}: {} offered, {} completed, {} rejected, wall {}",
            self.offered,
            self.completed(),
            self.queue.rejected,
            human_duration(self.wall),
        );
        println!(
            "        throughput {:.1} req/s | peak queue depth {} | {} \
             batches, {:.1}% padding | {} deadline misses",
            self.throughput_rps(),
            self.queue.peak_depth,
            self.batches(),
            self.padding_fraction() * 100.0,
            self.deadline_misses(),
        );
        if let Some(s) = self.latency.summary() {
            println!(
                "        latency p50 {}  p95 {}  p99 {}  max {}",
                human_duration(s.p50),
                human_duration(s.p95),
                human_duration(s.p99),
                human_duration(s.max),
            );
        }
        for w in &self.workers {
            println!(
                "        worker {}: {} requests in {} batches, busy {}",
                w.worker,
                w.requests,
                w.batches,
                human_duration(w.busy),
            );
        }
    }
}

/// Run the serving engine with a caller-supplied executor factory.
///
/// `make_executor(worker_id)` is called once *inside* each worker
/// thread (PJRT literals are thread-local); `make_image(request_id)`
/// produces each request's flattened image row on the generator
/// thread.  `buckets` are the dispatchable batch sizes (ascending;
/// the last is the max batch — see [`BatcherConfig`]).
pub fn run<E, F, G>(
    cfg: &ServeConfig,
    buckets: Vec<usize>,
    make_executor: F,
    mut make_image: G,
) -> Result<ServeReport>
where
    E: BatchExecutor,
    F: Fn(usize) -> Result<E> + Sync,
    G: FnMut(u64) -> Vec<f32>,
{
    cfg.validate()?;
    let bcfg = BatcherConfig::new(buckets, cfg.flush_timeout())?;
    let queue = RequestQueue::new(cfg.queue_capacity);
    let schedule =
        loadgen::poisson_offsets(cfg.requests, cfg.arrival_rate, cfg.seed);
    let deadline = cfg.deadline();
    // Workers build their executors (compiles are already cached, but
    // per-worker param replication runs the init artifact) *behind*
    // this barrier, so startup cost never pollutes the measured
    // latencies or throughput.
    let ready = std::sync::Barrier::new(cfg.workers + 1);

    let (workers, t_start) = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.workers)
            .map(|w| {
                let queue = &queue;
                let bcfg = &bcfg;
                let make_executor = &make_executor;
                let ready = &ready;
                scope.spawn(move || {
                    let exec = make_executor(w);
                    // Always pass the barrier — success or not — or
                    // the producer would wait forever.
                    ready.wait();
                    let out = match exec {
                        Ok(mut exec) => {
                            worker_loop(w, &mut exec, queue, bcfg)
                        }
                        Err(e) => Err(e),
                    };
                    if out.is_err() {
                        // A dead worker must not wedge the producer or
                        // starve its peers: stop arrivals, let the
                        // rest drain what is queued.
                        queue.close();
                    }
                    out
                })
            })
            .collect();

        ready.wait();
        let t_start = Instant::now();

        // This thread is the arrival process.
        for (i, off) in schedule.iter().enumerate() {
            let at = t_start + *off;
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
            let req = Request::new(i as u64, make_image(i as u64), deadline);
            let admitted = if cfg.open_loop {
                queue.try_enqueue(req)
            } else {
                queue.enqueue(req)
            };
            // Closed-loop enqueue only fails when the queue closed;
            // open-loop rejects on a full queue too, so check which.
            // Either way a closed queue (worker failure) means no
            // arrival can ever land again — stop generating.
            if !admitted && queue.is_closed() {
                break;
            }
        }
        queue.close();

        let reports = handles
            .into_iter()
            .map(|h| h.join().expect("serve worker panicked"))
            .collect::<Result<Vec<_>>>()?;
        Ok::<_, anyhow::Error>((reports, t_start))
    })?;

    let mut latency = LatencyHistogram::new();
    for w in &workers {
        latency.merge(&w.latency);
    }
    Ok(ServeReport {
        wall: t_start.elapsed(),
        offered: cfg.requests,
        queue: queue.stats(),
        latency,
        workers,
    })
}

/// Which forward artifacts exist for power-of-two bucket sizes up to
/// `cfg.max_batch` (manifest presence only — nothing is compiled).
pub fn discover_buckets(
    store: &ArtifactStore,
    cfg: &ServeConfig,
) -> Vec<usize> {
    let mut buckets = Vec::new();
    let mut b = 1usize;
    loop {
        if b >= cfg.max_batch {
            b = cfg.max_batch;
        }
        if store.manifest(&cfg.fwd_artifact(b)).is_ok() {
            buckets.push(b);
        }
        if b == cfg.max_batch {
            break;
        }
        b *= 2;
    }
    buckets
}

/// The real serving path: discover + compile the forward artifacts,
/// replicate parameters per worker, and drive synthetic traffic
/// through the engine.
pub fn run_with_artifacts(
    store: &mut ArtifactStore,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    cfg.validate()?;
    let buckets = discover_buckets(store, cfg);
    if buckets.is_empty() {
        bail!(
            "no forward artifacts for model {} precision {} (expected \
             e.g. {} in {}) — run `make artifacts`",
            cfg.model,
            cfg.precision.tag(),
            cfg.fwd_artifact(cfg.max_batch),
            store.dir().display()
        );
    }
    let fwd_by_bucket = buckets
        .iter()
        .map(|&b| Ok((b, store.load(&cfg.fwd_artifact(b))?)))
        .collect::<Result<Vec<_>>>()?;
    let init = store.load(&cfg.init_artifact())?;

    let preset = model_preset(&cfg.model)?;
    let dataset = SyntheticDataset::new(&preset, cfg.seed);
    let seed = cfg.seed as i32;

    let make_executor = |_worker: usize| {
        ArtifactExecutor::new(&init, fwd_by_bucket.clone(), seed)
    };
    // One fresh synthetic image per request (request id = batch index
    // of a single-row batch, so the stream is deterministic).
    let make_image = |i: u64| dataset.batch(i, 1, 7).images;

    run(cfg, buckets, make_executor, make_image)
}
