//! `mpx::serve` — continuous-batching, multi-model inference serving.
//!
//! Inference is where mixed precision pays off with no loss-scaling
//! caveats at all (paper §3): the f16/bf16 forward artifacts can be
//! driven straight at traffic.  This subsystem turns the AOT forward
//! artifacts into a measurable throughput/latency story:
//!
//! ```text
//!   loadgen ── merged per-lane Poisson timelines, paced on Clock
//!      │        (open loop: reject on full; closed loop: backpressure)
//!      ▼
//!   lane queues ── one RequestQueue per (model, precision) lane
//!      │ │ │        (bounded, admission-counted, Clock-stamped)
//!      ▼ ▼ ▼
//!   Scheduler ── weighted-deficit lane picker + continuous refill:
//!      │          a worker slot that frees immediately takes the
//!      │          largest exactly-fillable bucket from the picked
//!      │          lane (flush-on-timeout pads sub-bucket remainders)
//!      ▼
//!   worker pool ── shared across lanes; one executor per lane per
//!      │            worker; autoscaled (spawn/retire) off backlog
//!      ▼
//!   completions ── streamed per request via CompletionFn the moment
//!                  a batch finishes; per-lane histograms merge into
//!                  ServeReport (rank-interpolated quantiles)
//! ```
//!
//! Module layout:
//!
//! * [`clock`] — the [`Clock`] trait: [`WallClock`] in production,
//!   [`VirtualClock`] in tests; every timestamp in the subsystem is a
//!   `Duration` offset from the clock epoch.
//! * [`queue`] — bounded per-lane MPMC request queue + admission
//!   control, with a non-blocking poll/pop interface.
//! * [`batcher`] — the pure batching/refill policy (size buckets,
//!   padding, flush-on-timeout, [`SchedPolicy`]) and [`FormedBatch`].
//! * [`sched`] — the [`Scheduler`] state machine (lane picking,
//!   completion streaming, autoscaling) and the deterministic
//!   [`simulate`] harness.
//! * [`worker`] — [`BatchExecutor`] trait, the worker loop, and the
//!   PJRT-artifact executor.
//! * [`loadgen`] — deterministic Poisson arrival schedules, merged
//!   across lanes.
//!
//! Entry points: [`run`] (single lane, any executor — tests use a
//! fake), [`run_lanes`] (multi-model), and [`run_with_artifacts`]
//! (the real PJRT path `mpx serve` drives).
//!
//! # Testing with `VirtualClock`
//!
//! Every timing-dependent policy in the subsystem is driven through
//! plain-`Duration` timestamps, so it can be proven without a single
//! real sleep:
//!
//! * *Pure decisions* — [`batcher::refill`] and
//!   [`queue::RequestQueue::poll`] take `now` explicitly; feed them
//!   fabricated instants.
//! * *Whole-system replays* — [`sched::simulate`] runs the exact
//!   production [`Scheduler`] single-threaded over an event heap on a
//!   [`VirtualClock`]: arrivals, executions (a linear service-time
//!   model), flush timers, deadline misses, and autoscale steps all
//!   happen at exact virtual instants, so `rust/tests/serve_sim.rs`
//!   asserts *equalities* (flush fires at exactly `flush_timeout`;
//!   2:1 lane weights give exactly 2:1 service) rather than sleeping
//!   and hoping.  Same spec in, bit-identical report out.
//!
//! The threaded engine below shares all of that policy code; only the
//! blocking waits (`Condvar`) and real executors differ.

pub mod batcher;
pub mod clock;
pub mod loadgen;
pub mod queue;
pub mod sched;
pub mod worker;

pub use batcher::{
    decide, refill, BatcherConfig, Decision, FormedBatch, SchedPolicy,
};
pub use clock::{Clock, VirtualClock, WallClock};
pub use queue::{QueuePoll, QueueStats, Request, RequestQueue};
pub use sched::{
    simulate, AutoscalePolicy, Completion, CompletionFn, LaneLoad, LaneSpec,
    PollWork, ScaleOp, Scheduler, SimBatch, SimCompletion, SimLaneReport,
    SimReport, SimSpec, Work,
};
pub use worker::{BatchExecutor, LaneTally, WorkerReport};

#[cfg(feature = "xla")]
pub use worker::ArtifactExecutor;

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::metrics::{LatencyHistogram, NamedHistograms};
use crate::util::human_duration;
use worker::worker_loop;

#[cfg(feature = "xla")]
use anyhow::bail;

#[cfg(feature = "xla")]
use crate::config::{model_preset, Precision};

#[cfg(feature = "xla")]
use crate::data::SyntheticDataset;

#[cfg(feature = "xla")]
use crate::runtime::{Artifact, ArtifactStore};

/// One lane's offered production load.
pub struct LaneTraffic {
    pub spec: LaneSpec,
    /// Requests the generator offers this lane.
    pub requests: u64,
    /// Poisson rate (req/s); ≤ 0 means back-to-back.
    pub arrival_rate: f64,
}

/// Engine-level knobs shared by all lanes.
#[derive(Debug, Clone, Copy)]
pub struct EngineOpts {
    pub policy: SchedPolicy,
    pub autoscale: AutoscalePolicy,
    /// Open loop drops on a full lane; closed loop blocks instead.
    pub open_loop: bool,
    pub seed: u64,
}

/// Per-lane slice of a run report.
#[derive(Debug, Clone)]
pub struct LaneReport {
    pub name: String,
    pub accepted: u64,
    pub rejected: u64,
    pub rejected_closed: u64,
    pub peak_depth: usize,
    pub batches: u64,
    pub padded: u64,
    pub deadline_misses: u64,
    /// Real requests only; completed = `latency.count()`.
    pub latency: LatencyHistogram,
}

impl LaneReport {
    pub fn completed(&self) -> u64 {
        self.latency.count() as u64
    }
}

/// Aggregate result of one serving run.
#[derive(Debug)]
pub struct ServeReport {
    /// Wall clock from generator start to full drain.
    pub wall: Duration,
    /// Requests the load generator offered (accepted + rejected).
    pub offered: u64,
    /// Aggregate admission stats (sums across lanes; `peak_depth` is
    /// the max single-lane peak).
    pub queue: QueueStats,
    /// All workers' and lanes' latencies merged (real requests only).
    pub latency: LatencyHistogram,
    pub lanes: Vec<LaneReport>,
    pub workers: Vec<WorkerReport>,
    /// Workers autoscaling added beyond the initial pool.
    pub spawned: usize,
    /// Workers autoscaling retired.
    pub retired: usize,
}

impl ServeReport {
    pub fn completed(&self) -> u64 {
        self.latency.count() as u64
    }

    pub fn batches(&self) -> u64 {
        self.workers.iter().map(|w| w.batches()).sum()
    }

    pub fn padded(&self) -> u64 {
        self.workers.iter().map(|w| w.padded()).sum()
    }

    pub fn deadline_misses(&self) -> u64 {
        self.workers.iter().map(|w| w.deadline_misses()).sum()
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Share of executed rows that were padding ballast.
    pub fn padding_fraction(&self) -> f64 {
        let real = self.completed();
        let pad = self.padded();
        if real + pad == 0 {
            0.0
        } else {
            pad as f64 / (real + pad) as f64
        }
    }

    /// Per-lane latency histograms keyed by lane name.
    pub fn lane_histograms(&self) -> NamedHistograms {
        let mut set = NamedHistograms::new();
        for lane in &self.lanes {
            set.entry(&lane.name).merge(&lane.latency);
        }
        set
    }

    /// Human-readable run summary on stdout.
    pub fn print(&self, label: &str) {
        println!(
            "[serve] {label}: {} offered, {} completed, {} rejected, wall {}",
            self.offered,
            self.completed(),
            self.queue.rejected,
            human_duration(self.wall),
        );
        println!(
            "        throughput {:.1} req/s | peak queue depth {} | {} \
             batches, {:.1}% padding | {} deadline misses | {} spawned, {} \
             retired",
            self.throughput_rps(),
            self.queue.peak_depth,
            self.batches(),
            self.padding_fraction() * 100.0,
            self.deadline_misses(),
            self.spawned,
            self.retired,
        );
        if let Some(s) = self.latency.summary() {
            println!(
                "        latency p50 {}  p95 {}  p99 {}  max {}",
                human_duration(s.p50),
                human_duration(s.p95),
                human_duration(s.p99),
                human_duration(s.max),
            );
        }
        let lane_hists = self.lane_histograms();
        for lane in &self.lanes {
            let p99 = lane_hists
                .get(&lane.name)
                .and_then(|h| h.quantile(0.99))
                .map(human_duration)
                .unwrap_or_else(|| "-".into());
            println!(
                "        lane {}: {} completed ({} rejected) in {} batches, \
                 {} misses, p99 {}",
                lane.name,
                lane.completed(),
                lane.rejected,
                lane.batches,
                lane.deadline_misses,
                p99,
            );
        }
        for w in &self.workers {
            println!(
                "        worker {}: {} requests in {} batches, busy {}{}",
                w.worker,
                w.requests(),
                w.batches(),
                human_duration(w.busy),
                if w.retired { " (retired)" } else { "" },
            );
        }
    }
}

/// Multi-lane serving engine with a caller-supplied executor factory.
///
/// `make_executor(worker_id, lane)` is called once per lane *inside*
/// each worker thread (PJRT literals are thread-local);
/// `make_image(lane, request_id)` produces each request's flattened
/// image row on the generator thread.  `on_complete` (optional)
/// streams every request's completion as its batch finishes.
///
/// The initial pool is `opts.autoscale.min_workers` threads built
/// behind a barrier (startup cost never pollutes the measured
/// latencies); autoscaling may spawn up to `max_workers` while the
/// generator runs, and retire them as backlog falls.
pub fn run_lanes<E, F, G>(
    opts: &EngineOpts,
    lanes: Vec<LaneTraffic>,
    clock: Arc<dyn Clock>,
    make_executor: F,
    mut make_image: G,
    on_complete: Option<Box<CompletionFn>>,
) -> Result<ServeReport>
where
    E: BatchExecutor,
    F: Fn(usize, usize) -> Result<E> + Sync,
    G: FnMut(usize, u64) -> Vec<f32>,
{
    let offered: u64 = lanes.iter().map(|l| l.requests).sum();
    let deadlines: Vec<Duration> =
        lanes.iter().map(|l| l.spec.deadline).collect();
    let schedule = loadgen::merged_schedule(
        &lanes
            .iter()
            .map(|l| (l.requests, l.arrival_rate))
            .collect::<Vec<_>>(),
        opts.seed,
    );
    let nlanes = lanes.len();
    let sched = Scheduler::new(
        lanes.into_iter().map(|l| l.spec).collect(),
        opts.policy,
        opts.autoscale,
        clock.clone(),
        on_complete,
    )?;

    let n0 = opts.autoscale.min_workers;
    // Initial workers build their executors (compiles are already
    // cached, but per-worker param replication runs the init
    // artifact) *behind* this barrier, so startup cost never pollutes
    // the measured latencies or throughput.
    let ready = std::sync::Barrier::new(n0 + 1);

    let (workers, wall) = std::thread::scope(|scope| {
        let sched = &sched;
        let make_executor = &make_executor;
        let ready = &ready;
        let clock_ref: &dyn Clock = &*clock;

        let spawn_worker = |w: usize, with_barrier: bool| {
            scope.spawn(move || {
                let execs: Result<Vec<E>> =
                    (0..nlanes).map(|lane| make_executor(w, lane)).collect();
                // Always pass the barrier — success or not — or the
                // producer would wait forever.
                if with_barrier {
                    ready.wait();
                }
                let out = match execs {
                    Ok(mut execs) => {
                        worker_loop(w, &mut execs, sched, clock_ref)
                    }
                    Err(e) => {
                        sched.worker_aborted();
                        Err(e)
                    }
                };
                if out.is_err() {
                    // A dead worker must not wedge the producer or
                    // starve its peers: stop arrivals, let the rest
                    // drain what is queued.
                    sched.close_all();
                }
                out
            })
        };

        sched.register_workers(n0);
        let mut handles: Vec<_> =
            (0..n0).map(|w| spawn_worker(w, true)).collect();
        let mut next_worker = n0;

        ready.wait();
        let t_start = clock.now();

        // This thread is the arrival process.
        for arr in &schedule {
            loadgen::pace(clock_ref, t_start, arr.at);
            let req = Request::new(
                arr.idx,
                make_image(arr.lane, arr.idx),
                deadlines[arr.lane],
                clock.now(),
            );
            let admitted = if opts.open_loop {
                sched.submit(arr.lane, req)
            } else {
                sched.submit_blocking(arr.lane, req)
            };
            // Closed-loop submission only fails when the lane closed;
            // open-loop rejects on a full lane too, so check which.
            // Either way fully-closed lanes (worker failure) mean no
            // arrival can ever land again — stop generating.
            if !admitted && sched.all_closed() {
                break;
            }
            if let ScaleOp::Spawn(k) = sched.poll_autoscale() {
                sched.register_workers(k);
                for _ in 0..k {
                    handles.push(spawn_worker(next_worker, false));
                    next_worker += 1;
                }
            }
        }
        sched.close_all();

        let reports = handles
            .into_iter()
            .map(|h| h.join().expect("serve worker panicked"))
            .collect::<Result<Vec<_>>>()?;
        Ok::<_, anyhow::Error>((reports, clock.now().saturating_sub(t_start)))
    })?;

    // Aggregate: per-lane stats + tallies, all-lane latency merge.
    let mut latency = LatencyHistogram::new();
    let mut queue = QueueStats::default();
    let mut lane_reports = Vec::with_capacity(nlanes);
    for lane in 0..nlanes {
        let qs = sched.lane_stats(lane);
        queue.accepted += qs.accepted;
        queue.rejected += qs.rejected;
        queue.rejected_closed += qs.rejected_closed;
        queue.peak_depth = queue.peak_depth.max(qs.peak_depth);
        let mut lr = LaneReport {
            name: sched.lane_name(lane).to_string(),
            accepted: qs.accepted,
            rejected: qs.rejected,
            rejected_closed: qs.rejected_closed,
            peak_depth: qs.peak_depth,
            batches: 0,
            padded: 0,
            deadline_misses: 0,
            latency: LatencyHistogram::new(),
        };
        for w in &workers {
            let t = &w.lanes[lane];
            lr.batches += t.batches;
            lr.padded += t.padded;
            lr.deadline_misses += t.deadline_misses;
            lr.latency.merge(&t.latency);
        }
        latency.merge(&lr.latency);
        lane_reports.push(lr);
    }
    let counters = sched.counters();
    Ok(ServeReport {
        wall,
        offered,
        queue,
        latency,
        lanes: lane_reports,
        workers,
        spawned: counters.spawned.saturating_sub(n0),
        retired: counters.retired,
    })
}

/// Engine options derived from a [`ServeConfig`].
pub fn engine_opts(cfg: &ServeConfig) -> EngineOpts {
    EngineOpts {
        policy: cfg.policy,
        autoscale: autoscale_policy(cfg),
        open_loop: cfg.open_loop,
        seed: cfg.seed,
    }
}

/// Autoscale policy from config: `max_workers > workers` turns
/// scaling on; `autoscale_depth` (0 ⇒ `max_batch`) is the backlog one
/// worker absorbs before the pool grows.
pub fn autoscale_policy(cfg: &ServeConfig) -> AutoscalePolicy {
    if cfg.max_workers > cfg.workers {
        AutoscalePolicy {
            min_workers: cfg.workers,
            max_workers: cfg.max_workers,
            depth_per_worker: if cfg.autoscale_depth == 0 {
                cfg.max_batch
            } else {
                cfg.autoscale_depth
            },
        }
    } else {
        AutoscalePolicy::fixed(cfg.workers)
    }
}

/// Single-lane engine (the PR-1 entry point, unchanged signature):
/// `make_executor(worker_id)` builds the one lane's executor inside
/// each worker thread; `make_image(request_id)` produces image rows
/// on the generator thread.  `buckets` are the dispatchable batch
/// sizes (ascending; the last is the max batch).
pub fn run<E, F, G>(
    cfg: &ServeConfig,
    buckets: Vec<usize>,
    make_executor: F,
    mut make_image: G,
) -> Result<ServeReport>
where
    E: BatchExecutor,
    F: Fn(usize) -> Result<E> + Sync,
    G: FnMut(u64) -> Vec<f32>,
{
    cfg.validate()?;
    let spec = LaneSpec {
        name: format!("{}/{}", cfg.model, cfg.precision.tag()),
        weight: 1,
        batcher: BatcherConfig::new(buckets, cfg.flush_timeout())?,
        queue_capacity: cfg.queue_capacity,
        deadline: cfg.deadline(),
    };
    run_lanes(
        &engine_opts(cfg),
        vec![LaneTraffic {
            spec,
            requests: cfg.requests,
            arrival_rate: cfg.arrival_rate,
        }],
        Arc::new(WallClock::new()),
        |w, _lane| make_executor(w),
        |_lane, i| make_image(i),
        None,
    )
}

/// Which forward artifacts exist for power-of-two bucket sizes up to
/// `cfg.max_batch` (manifest presence only — nothing is compiled).
#[cfg(feature = "xla")]
pub fn discover_buckets(
    store: &ArtifactStore,
    cfg: &ServeConfig,
    precision: Precision,
) -> Vec<usize> {
    let mut buckets = Vec::new();
    let mut b = 1usize;
    loop {
        if b >= cfg.max_batch {
            b = cfg.max_batch;
        }
        if store.manifest(&cfg.fwd_artifact_for(precision, b)).is_ok() {
            buckets.push(b);
        }
        if b == cfg.max_batch {
            break;
        }
        b *= 2;
    }
    buckets
}

/// The real serving path: discover + compile the forward artifacts
/// for every configured (model, precision) lane, replicate parameters
/// per worker per lane, and drive synthetic traffic through the
/// engine.  `cfg.requests` and `cfg.arrival_rate` are split evenly
/// across lanes; lane weights shape the *service*, not the offer.
#[cfg(feature = "xla")]
pub fn run_with_artifacts(
    store: &mut ArtifactStore,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    cfg.validate()?;
    struct LaneArtifacts {
        init: Arc<Artifact>,
        fwd: Vec<(usize, Arc<Artifact>)>,
    }

    let lane_precisions = cfg.effective_lanes();
    let n = lane_precisions.len() as u64;
    let base_requests = cfg.requests / n;
    let rate = if cfg.arrival_rate > 0.0 {
        cfg.arrival_rate / n as f64
    } else {
        0.0
    };

    let mut lane_arts = Vec::new();
    let mut traffic = Vec::new();
    for (i, &(precision, weight)) in lane_precisions.iter().enumerate() {
        let buckets = discover_buckets(store, cfg, precision);
        if buckets.is_empty() {
            bail!(
                "no forward artifacts for model {} precision {} (expected \
                 e.g. {} in {}) — run `make artifacts`",
                cfg.model,
                precision.tag(),
                cfg.fwd_artifact_for(precision, cfg.max_batch),
                store.dir().display()
            );
        }
        let fwd = buckets
            .iter()
            .map(|&b| {
                Ok((b, store.load(&cfg.fwd_artifact_for(precision, b))?))
            })
            .collect::<Result<Vec<_>>>()?;
        let init = store.load(&cfg.init_artifact_for(precision))?;
        traffic.push(LaneTraffic {
            spec: LaneSpec {
                name: format!("{}/{}", cfg.model, precision.tag()),
                weight,
                batcher: BatcherConfig::new(buckets, cfg.flush_timeout())?,
                queue_capacity: cfg.queue_capacity,
                deadline: cfg.deadline(),
            },
            // Lane 0 absorbs the division remainder.
            requests: if i == 0 {
                cfg.requests - base_requests * (n - 1)
            } else {
                base_requests
            },
            arrival_rate: rate,
        });
        lane_arts.push(LaneArtifacts { init, fwd });
    }

    let preset = model_preset(&cfg.model)?;
    let dataset = SyntheticDataset::new(&preset, cfg.seed);
    let seed = cfg.seed as i32;

    let make_executor = |_worker: usize, lane: usize| {
        let la = &lane_arts[lane];
        ArtifactExecutor::new(&la.init, la.fwd.clone(), seed)
    };
    // One fresh synthetic image per request (request id = batch index
    // of a single-row batch, so the stream is deterministic).
    let make_image = |_lane: usize, i: u64| dataset.batch(i, 1, 7).images;

    run_lanes(
        &engine_opts(cfg),
        traffic,
        Arc::new(WallClock::new()),
        make_executor,
        make_image,
        None,
    )
}
