//! `mpx::serve` — continuous-batching, multi-model inference serving.
//!
//! Inference is where mixed precision pays off with no loss-scaling
//! caveats at all (paper §3): the f16/bf16 forward artifacts can be
//! driven straight at traffic.  This subsystem turns the AOT forward
//! artifacts into a measurable throughput/latency story:
//!
//! ```text
//!   loadgen ── merged per-lane Poisson timelines, paced on Clock
//!      │        (open loop: reject on full; closed loop: backpressure)
//!      ▼
//!   lane queues ── one RequestQueue per (model, precision) lane
//!      │ │ │        (bounded, admission-counted, Clock-stamped)
//!      ▼ ▼ ▼
//!   Scheduler ── weighted-deficit lane picker + continuous refill:
//!      │          a worker slot that frees immediately takes the
//!      │          largest exactly-fillable bucket from the picked
//!      │          lane (flush-on-timeout pads sub-bucket remainders)
//!      ▼
//!   worker pool ── shared across lanes; one executor per lane per
//!      │            worker; autoscaled (spawn/retire) off backlog
//!      ▼
//!   completions ── streamed per request via CompletionFn the moment
//!                  a batch finishes; per-lane histograms merge into
//!                  ServeReport (rank-interpolated quantiles)
//! ```
//!
//! Module layout:
//!
//! * [`clock`] — the [`Clock`] trait: [`WallClock`] in production,
//!   [`VirtualClock`] in tests; every timestamp in the subsystem is a
//!   `Duration` offset from the clock epoch.
//! * [`queue`] — bounded per-lane MPMC request queue + admission
//!   control, with a non-blocking poll/pop interface.
//! * [`batcher`] — the pure batching/refill policy (size buckets,
//!   padding, flush-on-timeout, [`SchedPolicy`]) and [`FormedBatch`].
//! * [`planner`] — the latency-aware bucket planner: from a per-lane
//!   offered-load profile (rate, size distribution, p99 deadline) it
//!   selects which batch sizes to AOT-compile and which flush
//!   timeouts to run, minimizing expected padding under the SLO —
//!   replacing the static everything-that-was-compiled bucket list.
//! * [`sched`] — the [`Scheduler`] state machine (lane picking,
//!   completion streaming, autoscaling) and the deterministic
//!   [`simulate`] harness.
//! * [`worker`] — [`BatchExecutor`] trait, the worker loop, and the
//!   PJRT-artifact executor.
//! * [`loadgen`] — deterministic Poisson arrival schedules, merged
//!   across lanes.
//! * [`transport`] — the HTTP/1.1 network layer: `mpx serve
//!   --listen` runs a single-threaded poll reactor (keep-alive and
//!   pipelined connections, whole-request read deadlines, a
//!   connection budget decoupled from the worker pool) that accepts
//!   `POST /v1/infer`, streams each completion back over chunked
//!   transfer encoding the moment continuous batching frees its
//!   slot, maps admission control onto status codes (429/503/404),
//!   and exports `GET /healthz` + `GET /metrics` (Prometheus);
//!   `transport::client` is the std-only client the loadgen and the
//!   integration tests drive it with.
//!
//! Entry points: [`run`] (single lane, any executor — tests use a
//! fake), [`run_lanes`] (multi-model), and `run_with_artifacts`
//! (the real PJRT path `mpx serve` drives; needs the `xla` feature).
//! [`plan_for_config`] turns a [`ServeConfig`] into a
//! [`planner::Plan`] without touching artifacts — `mpx serve --plan`
//! prints it, `run_with_artifacts` serves it.
//!
//! # Testing with `VirtualClock`
//!
//! Every timing-dependent policy in the subsystem is driven through
//! plain-`Duration` timestamps, so it can be proven without a single
//! real sleep:
//!
//! * *Pure decisions* — [`batcher::refill`] and
//!   [`queue::RequestQueue::poll`] take `now` explicitly; feed them
//!   fabricated instants.
//! * *Whole-system replays* — [`sched::simulate`] runs the exact
//!   production [`Scheduler`] single-threaded over an event heap on a
//!   [`VirtualClock`]: arrivals, executions (a linear service-time
//!   model), flush timers, deadline misses, and autoscale steps all
//!   happen at exact virtual instants, so `rust/tests/serve_sim.rs`
//!   asserts *equalities* (flush fires at exactly `flush_timeout`;
//!   2:1 lane weights give exactly 2:1 service) rather than sleeping
//!   and hoping.  Same spec in, bit-identical report out.
//!
//! The threaded engine below shares all of that policy code; only the
//! blocking waits (`Condvar`) and real executors differ.

pub mod batcher;
pub mod calibrate;
pub mod clock;
pub mod loadgen;
pub mod planner;
pub mod queue;
pub mod sched;
pub mod transport;
pub mod worker;

pub use batcher::{
    decide, refill, BatcherConfig, Decision, FormedBatch, SchedPolicy,
};
pub use calibrate::{
    Calibration, DriftConfig, DriftMonitor, LaneFit, ReplanDriver,
    ReplanSpec, CALIBRATION_FILE,
};
pub use planner::{
    LanePlan, LaneProfile, Plan, PlanEstimate, PlanVerdict, PlannerConfig,
    ServiceModel,
};
pub use clock::{Clock, VirtualClock, WallClock};
pub use queue::{QueuePoll, QueueStats, Request, RequestQueue};
pub use sched::{
    simulate, AdoptOutcome, AutoscalePolicy, Completion, CompletionFn,
    LaneLoad, LaneRetune, LaneSpec, PollWork, ScaleOp, Scheduler, SimBatch,
    SimCompletion, SimLaneReport, SimReplan, SimReport, SimSpec, Work,
};
pub use transport::{Server, ServerHandle, TransportReport};
pub use worker::{
    ArtifactExecutor, BatchExecutor, LaneTally, WorkerReport,
};

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::{model_preset, LaneConfig, Precision, ServeConfig};
use crate::data::SyntheticDataset;
use crate::metrics::{LatencyHistogram, NamedHistograms};
use crate::runtime::{Artifact, ArtifactStore};
use crate::trace::{Span, TraceConfig, Tracer};
use crate::util::human_duration;
use worker::worker_loop;

/// One lane's offered production load.
pub struct LaneTraffic {
    pub spec: LaneSpec,
    /// Requests the generator offers this lane.
    pub requests: u64,
    /// Poisson rate (req/s); ≤ 0 means back-to-back.
    pub arrival_rate: f64,
}

/// Engine-level knobs shared by all lanes.
#[derive(Debug, Clone)]
pub struct EngineOpts {
    pub policy: SchedPolicy,
    pub autoscale: AutoscalePolicy,
    /// Open loop drops on a full lane; closed loop blocks instead.
    pub open_loop: bool,
    pub seed: u64,
    /// Span tracing (`[trace]` table); disabled by default.
    pub trace: TraceConfig,
}

/// Per-lane slice of a run report.
#[derive(Debug, Clone)]
pub struct LaneReport {
    pub name: String,
    pub accepted: u64,
    pub rejected: u64,
    pub rejected_closed: u64,
    pub peak_depth: usize,
    pub batches: u64,
    pub padded: u64,
    pub deadline_misses: u64,
    /// Real requests only; completed = `latency.count()`.
    pub latency: LatencyHistogram,
}

impl LaneReport {
    pub fn completed(&self) -> u64 {
        self.latency.count() as u64
    }
}

/// Aggregate result of one serving run.
#[derive(Debug)]
pub struct ServeReport {
    /// Wall clock from generator start to full drain.
    pub wall: Duration,
    /// Requests the load generator offered (accepted + rejected).
    pub offered: u64,
    /// Aggregate admission stats (sums across lanes; `peak_depth` is
    /// the max single-lane peak).
    pub queue: QueueStats,
    /// All workers' and lanes' latencies merged (real requests only).
    pub latency: LatencyHistogram,
    pub lanes: Vec<LaneReport>,
    pub workers: Vec<WorkerReport>,
    /// Workers autoscaling added beyond the initial pool.
    pub spawned: usize,
    /// Workers autoscaling retired.
    pub retired: usize,
    /// Tracer snapshot in `(start, seq)` order; empty when tracing
    /// was off.
    pub spans: Vec<Span>,
    /// Spans the tracer's ring dropped (oldest-first) — non-zero
    /// means `spans` misses the start of the timeline.
    pub trace_dropped: u64,
}

impl ServeReport {
    pub fn completed(&self) -> u64 {
        self.latency.count() as u64
    }

    pub fn batches(&self) -> u64 {
        self.workers.iter().map(|w| w.batches()).sum()
    }

    pub fn padded(&self) -> u64 {
        self.workers.iter().map(|w| w.padded()).sum()
    }

    pub fn deadline_misses(&self) -> u64 {
        self.workers.iter().map(|w| w.deadline_misses()).sum()
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Share of executed rows that were padding ballast.
    pub fn padding_fraction(&self) -> f64 {
        let real = self.completed();
        let pad = self.padded();
        if real + pad == 0 {
            0.0
        } else {
            pad as f64 / (real + pad) as f64
        }
    }

    /// Per-lane latency histograms keyed by lane name.
    pub fn lane_histograms(&self) -> NamedHistograms {
        let mut set = NamedHistograms::new();
        for lane in &self.lanes {
            set.entry(&lane.name).merge(&lane.latency);
        }
        set
    }

    /// Human-readable run summary on stdout.
    pub fn print(&self, label: &str) {
        println!(
            "[serve] {label}: {} offered, {} completed, {} rejected, wall {}",
            self.offered,
            self.completed(),
            self.queue.rejected,
            human_duration(self.wall),
        );
        println!(
            "        throughput {:.1} req/s | peak queue depth {} | {} \
             batches, {:.1}% padding | {} deadline misses | {} spawned, {} \
             retired",
            self.throughput_rps(),
            self.queue.peak_depth,
            self.batches(),
            self.padding_fraction() * 100.0,
            self.deadline_misses(),
            self.spawned,
            self.retired,
        );
        if let Some(s) = self.latency.summary() {
            println!(
                "        latency p50 {}  p95 {}  p99 {}  max {}",
                human_duration(s.p50),
                human_duration(s.p95),
                human_duration(s.p99),
                human_duration(s.max),
            );
        }
        let lane_hists = self.lane_histograms();
        for lane in &self.lanes {
            let p99 = lane_hists
                .get(&lane.name)
                .and_then(|h| h.quantile(0.99))
                .map(human_duration)
                .unwrap_or_else(|| "-".into());
            println!(
                "        lane {}: {} completed ({} rejected) in {} batches, \
                 {} misses, p99 {}",
                lane.name,
                lane.completed(),
                lane.rejected,
                lane.batches,
                lane.deadline_misses,
                p99,
            );
        }
        for w in &self.workers {
            println!(
                "        worker {}: {} requests in {} batches, busy {}{}",
                w.worker,
                w.requests(),
                w.batches(),
                human_duration(w.busy),
                if w.retired { " (retired)" } else { "" },
            );
        }
    }
}

/// Multi-lane serving engine with a caller-supplied executor factory.
///
/// `make_executor(worker_id, lane)` is called once per lane *inside*
/// each worker thread (PJRT literals are thread-local);
/// `make_image(lane, request_id)` produces each request's flattened
/// image row on the generator thread.  `on_complete` (optional)
/// streams every request's completion as its batch finishes.
///
/// The initial pool is `opts.autoscale.min_workers` threads built
/// behind a barrier (startup cost never pollutes the measured
/// latencies); autoscaling may spawn up to `max_workers` while the
/// generator runs, and retire them as backlog falls.
pub fn run_lanes<E, F, G>(
    opts: &EngineOpts,
    lanes: Vec<LaneTraffic>,
    clock: Arc<dyn Clock>,
    make_executor: F,
    mut make_image: G,
    on_complete: Option<Box<CompletionFn>>,
) -> Result<ServeReport>
where
    E: BatchExecutor,
    F: Fn(usize, usize) -> Result<E> + Sync,
    G: FnMut(usize, u64) -> Vec<f32>,
{
    let offered: u64 = lanes.iter().map(|l| l.requests).sum();
    let deadlines: Vec<Duration> =
        lanes.iter().map(|l| l.spec.deadline).collect();
    let schedule = loadgen::merged_schedule(
        &lanes
            .iter()
            .map(|l| (l.requests, l.arrival_rate))
            .collect::<Vec<_>>(),
        opts.seed,
    );
    let nlanes = lanes.len();
    let mut sched = Scheduler::new(
        lanes.into_iter().map(|l| l.spec).collect(),
        opts.policy,
        opts.autoscale,
        clock.clone(),
        on_complete,
    )?;
    let tracer = Tracer::from_config(clock.clone(), &opts.trace);
    if let Some(t) = &tracer {
        sched.set_tracer(t.clone());
    }
    let sched = sched;

    let n0 = opts.autoscale.min_workers;
    // Initial workers build their executors (compiles are already
    // cached, but per-worker param replication runs the init
    // artifact) *behind* this barrier, so startup cost never pollutes
    // the measured latencies or throughput.
    let ready = std::sync::Barrier::new(n0 + 1);

    let (workers, wall) = std::thread::scope(|scope| {
        let sched = &sched;
        let make_executor = &make_executor;
        let ready = &ready;
        let clock_ref: &dyn Clock = &*clock;

        let spawn_worker = |w: usize, with_barrier: bool| {
            scope.spawn(move || {
                let execs: Result<Vec<E>> =
                    (0..nlanes).map(|lane| make_executor(w, lane)).collect();
                // Always pass the barrier — success or not — or the
                // producer would wait forever.
                if with_barrier {
                    ready.wait();
                }
                let out = match execs {
                    Ok(mut execs) => {
                        worker_loop(w, &mut execs, sched, clock_ref)
                    }
                    Err(e) => {
                        sched.worker_aborted();
                        Err(e)
                    }
                };
                if out.is_err() {
                    // A dead worker must not wedge the producer or
                    // starve its peers: stop arrivals, let the rest
                    // drain what is queued.
                    sched.close_all();
                }
                out
            })
        };

        sched.register_workers(n0);
        let mut handles: Vec<_> =
            (0..n0).map(|w| spawn_worker(w, true)).collect();
        let mut next_worker = n0;

        ready.wait();
        let t_start = clock.now();

        // This thread is the arrival process.
        for arr in &schedule {
            loadgen::pace(clock_ref, t_start, arr.at);
            let req = Request::new(
                arr.idx,
                make_image(arr.lane, arr.idx),
                deadlines[arr.lane],
                clock.now(),
            );
            let admitted = if opts.open_loop {
                sched.submit(arr.lane, req)
            } else {
                sched.submit_blocking(arr.lane, req)
            };
            // Closed-loop submission only fails when the lane closed;
            // open-loop rejects on a full lane too, so check which.
            // Either way fully-closed lanes (worker failure) mean no
            // arrival can ever land again — stop generating.
            if !admitted && sched.all_closed() {
                break;
            }
            if let ScaleOp::Spawn(k) = sched.poll_autoscale() {
                sched.register_workers(k);
                for _ in 0..k {
                    handles.push(spawn_worker(next_worker, false));
                    next_worker += 1;
                }
            }
        }
        sched.close_all();

        let reports = handles
            .into_iter()
            .map(|h| h.join().expect("serve worker panicked"))
            .collect::<Result<Vec<_>>>()?;
        Ok::<_, anyhow::Error>((reports, clock.now().saturating_sub(t_start)))
    })?;

    // Aggregate: per-lane stats + tallies, all-lane latency merge.
    let mut latency = LatencyHistogram::new();
    let mut queue = QueueStats::default();
    let mut lane_reports = Vec::with_capacity(nlanes);
    for lane in 0..nlanes {
        let qs = sched.lane_stats(lane);
        queue.accepted += qs.accepted;
        queue.rejected += qs.rejected;
        queue.rejected_closed += qs.rejected_closed;
        queue.peak_depth = queue.peak_depth.max(qs.peak_depth);
        let mut lr = LaneReport {
            name: sched.lane_name(lane).to_string(),
            accepted: qs.accepted,
            rejected: qs.rejected,
            rejected_closed: qs.rejected_closed,
            peak_depth: qs.peak_depth,
            batches: 0,
            padded: 0,
            deadline_misses: 0,
            latency: LatencyHistogram::new(),
        };
        for w in &workers {
            let t = &w.lanes[lane];
            lr.batches += t.batches;
            lr.padded += t.padded;
            lr.deadline_misses += t.deadline_misses;
            lr.latency.merge(&t.latency);
        }
        latency.merge(&lr.latency);
        lane_reports.push(lr);
    }
    let counters = sched.counters();
    let (spans, trace_dropped) = match &tracer {
        Some(t) => (t.snapshot(), t.dropped()),
        None => (Vec::new(), 0),
    };
    Ok(ServeReport {
        wall,
        offered,
        queue,
        latency,
        lanes: lane_reports,
        workers,
        spawned: counters.spawned.saturating_sub(n0),
        retired: counters.retired,
        spans,
        trace_dropped,
    })
}

/// Engine options derived from a [`ServeConfig`].
pub fn engine_opts(cfg: &ServeConfig) -> EngineOpts {
    EngineOpts {
        policy: cfg.policy,
        autoscale: autoscale_policy(cfg),
        open_loop: cfg.open_loop,
        seed: cfg.seed,
        trace: cfg.trace.clone(),
    }
}

/// Autoscale policy from config: `max_workers > workers` turns
/// scaling on; `autoscale_depth` (0 ⇒ `max_batch`) is the backlog one
/// worker absorbs before the pool grows.
pub fn autoscale_policy(cfg: &ServeConfig) -> AutoscalePolicy {
    if cfg.max_workers > cfg.workers {
        AutoscalePolicy {
            min_workers: cfg.workers,
            max_workers: cfg.max_workers,
            depth_per_worker: if cfg.autoscale_depth == 0 {
                cfg.max_batch
            } else {
                cfg.autoscale_depth
            },
        }
    } else {
        AutoscalePolicy::fixed(cfg.workers)
    }
}

/// Build the bucket [`planner::Plan`] a [`ServeConfig`] describes:
/// candidates are the power-of-two ladder up to `max_batch` (the same
/// ladder `discover_buckets` probes artifacts for), the service model
/// and search knobs come from `[serve.planner]`, and one
/// [`LaneProfile`] is derived per configured lane.  Pure computation
/// — no artifacts, no xla — so `mpx serve --plan` and the tests can
/// run it anywhere.
pub fn plan_for_config(cfg: &ServeConfig) -> Result<planner::Plan> {
    cfg.validate()?;
    let profiles = lane_profiles(cfg);
    let pcfg = planner_config(cfg);
    let (models, _) = lane_service_models(cfg)?;
    planner::plan_with_models(&pcfg, &models, &profiles)
}

/// One [`planner::LaneProfile`] per configured lane — the offered
/// load the planner (and the live replanner) sizes buckets against.
pub fn lane_profiles(cfg: &ServeConfig) -> Vec<planner::LaneProfile> {
    cfg.lane_configs()
        .iter()
        .map(|lc| planner::LaneProfile {
            name: lc.name.clone(),
            rate: lc.rate,
            deadline: lc.deadline(),
            weight: lc.weight,
            size_dist: lc.size_dist(),
        })
        .collect()
}

/// The planner search knobs a [`ServeConfig`] describes (candidate
/// ladder, pool size, SLO headroom) — everything except the service
/// model, which [`lane_service_models`] resolves separately.
pub fn planner_config(cfg: &ServeConfig) -> planner::PlannerConfig {
    planner::PlannerConfig {
        candidates: planner::pow2_candidates(cfg.max_batch),
        workers: cfg.workers,
        max_compiled: cfg.planner.max_compiled,
        safety: cfg.planner.safety,
        max_flush: cfg.flush_timeout(),
    }
}

/// The stable (name, precision) identity of every configured lane, in
/// lane order — the key [`ServiceSample`] records and
/// `calibration.json` entries are filed under.  Names match the
/// [`LaneSpec`]s the engine runs (`<model>/<lane>`), so samples from a
/// run always join back to the lane that produced them.
pub fn lane_identities(cfg: &ServeConfig) -> Vec<crate::trace::LaneId> {
    cfg.lane_configs()
        .iter()
        .map(|lc| {
            crate::trace::LaneId::new(
                format!("{}/{}", cfg.model, lc.name),
                lc.precision.tag(),
            )
        })
        .collect()
}

/// Resolve each lane's linear [`planner::ServiceModel`] according to
/// `[serve.planner] source`:
///
/// * `"config"` — every lane gets the `overhead_us` / `per_row_us`
///   constants.
/// * `"calibrated"` — lanes with a fitted entry in the artifacts
///   directory's `calibration.json` use it; lanes without one (never
///   measured, or fit guard rejected the samples) fall back to the
///   config constants.
///
/// Returns one model per lane plus a per-lane flag saying whether the
/// measured fit was used — `mpx serve --plan` reports the fallback
/// rather than hiding it.
pub fn lane_service_models(
    cfg: &ServeConfig,
) -> Result<(Vec<planner::ServiceModel>, Vec<bool>)> {
    let fallback = planner::ServiceModel {
        overhead: Duration::from_micros(cfg.planner.overhead_us),
        per_row: Duration::from_micros(cfg.planner.per_row_us),
    };
    let ids = lane_identities(cfg);
    if cfg.planner.source != crate::config::PlannerSource::Calibrated {
        return Ok((vec![fallback; ids.len()], vec![false; ids.len()]));
    }
    let path = std::path::Path::new(&cfg.artifacts_dir)
        .join(calibrate::CALIBRATION_FILE);
    let cal = Calibration::read(&path)?;
    let mut models = Vec::with_capacity(ids.len());
    let mut calibrated = Vec::with_capacity(ids.len());
    for id in &ids {
        match cal.get(&id.name, &id.precision) {
            Some(fit) => {
                models.push(fit.model());
                calibrated.push(true);
            }
            None => {
                models.push(fallback);
                calibrated.push(false);
            }
        }
    }
    Ok((models, calibrated))
}

/// Split a total request budget across lanes in proportion to their
/// offered rates — the first *rated* lane absorbs the rounding
/// remainder, so zero-rate lanes are never offered stray requests.
/// An all-zero rate profile (back-to-back everywhere) splits evenly,
/// lane 0 taking the remainder — the legacy behaviour.
pub fn split_requests(total: u64, lanes: &[LaneConfig]) -> Vec<u64> {
    let n = lanes.len();
    if n == 0 {
        return Vec::new();
    }
    let rates: Vec<f64> = lanes.iter().map(|l| l.rate.max(0.0)).collect();
    let sum: f64 = rates.iter().sum();
    let mut out = vec![0u64; n];
    if sum <= 0.0 {
        let base = total / n as u64;
        for slot in out.iter_mut() {
            *slot = base;
        }
        out[0] += total - base * n as u64;
    } else {
        let mut assigned = 0u64;
        for i in 0..n {
            out[i] = (total as f64 * rates[i] / sum).floor() as u64;
            assigned += out[i];
        }
        let first_rated = rates
            .iter()
            .position(|&r| r > 0.0)
            .expect("sum > 0 implies a rated lane");
        out[first_rated] += total - assigned;
    }
    out
}

/// Single-lane engine (the PR-1 entry point, unchanged signature):
/// `make_executor(worker_id)` builds the one lane's executor inside
/// each worker thread; `make_image(request_id)` produces image rows
/// on the generator thread.  `buckets` are the dispatchable batch
/// sizes (ascending; the last is the max batch).
pub fn run<E, F, G>(
    cfg: &ServeConfig,
    buckets: Vec<usize>,
    make_executor: F,
    mut make_image: G,
) -> Result<ServeReport>
where
    E: BatchExecutor,
    F: Fn(usize) -> Result<E> + Sync,
    G: FnMut(u64) -> Vec<f32>,
{
    cfg.validate()?;
    let spec = LaneSpec {
        name: format!("{}/{}", cfg.model, cfg.precision.tag()),
        weight: 1,
        batcher: BatcherConfig::new(buckets, cfg.flush_timeout())?,
        queue_capacity: cfg.queue_capacity,
        deadline: cfg.deadline(),
    };
    run_lanes(
        &engine_opts(cfg),
        vec![LaneTraffic {
            spec,
            requests: cfg.requests,
            arrival_rate: cfg.arrival_rate,
        }],
        Arc::new(WallClock::new()),
        |w, _lane| make_executor(w),
        |_lane, i| make_image(i),
        None,
    )
}

/// Which forward artifacts exist for power-of-two bucket sizes up to
/// `cfg.max_batch` (manifest presence only — nothing is compiled).
/// Probes exactly [`planner::pow2_candidates`] — the one definition
/// of the ladder, shared with the planner's search space, so a
/// planned bucket is always discoverable when its artifact exists.
pub fn discover_buckets(
    store: &ArtifactStore,
    cfg: &ServeConfig,
    precision: Precision,
) -> Vec<usize> {
    planner::pow2_candidates(cfg.max_batch)
        .into_iter()
        .filter(|&b| {
            store.manifest(&cfg.fwd_artifact_for(precision, b)).is_ok()
        })
        .collect()
}

/// Planned buckets whose forward artifact is absent from `store` —
/// the one definition of "missing" shared by `mpx serve --plan`'s
/// presence report and [`run_with_artifacts`]'s hard error.
pub fn missing_planned_artifacts(
    store: &ArtifactStore,
    cfg: &ServeConfig,
    precision: Precision,
    plan: &LanePlan,
) -> Vec<usize> {
    plan.buckets
        .iter()
        .copied()
        .filter(|&b| {
            store.manifest(&cfg.fwd_artifact_for(precision, b)).is_err()
        })
        .collect()
}

/// The real serving path: discover + compile the forward artifacts
/// for every configured (model, precision) lane, replicate parameters
/// per worker per lane, and drive synthetic traffic through the
/// engine.
///
/// Each lane offers its own rate and owes its own deadline
/// (`[serve.lanes.*]`; the legacy flat keys still split one rate
/// evenly); `cfg.requests` is divided in proportion to the rates.
/// When the planner is on ([`ServeConfig::use_planner`]), each lane
/// serves its planned bucket subset and flush timeout instead of the
/// static everything-that-was-compiled list; a planned bucket whose
/// artifact is missing is a hard error naming the artifact (serving a
/// partial plan would silently void its SLO guarantees).
pub fn run_with_artifacts(
    store: &mut ArtifactStore,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    cfg.validate()?;
    let prepared = prepare_lanes(store, cfg)?;
    let lane_cfgs = prepared.lane_cfgs;
    let requests = split_requests(cfg.requests, &lane_cfgs);
    let traffic = prepared
        .specs
        .into_iter()
        .zip(&lane_cfgs)
        .zip(&requests)
        .map(|((spec, lc), &n)| LaneTraffic {
            spec,
            requests: n,
            arrival_rate: lc.rate,
        })
        .collect();
    let lane_arts = prepared.arts;

    let preset = model_preset(&cfg.model)?;
    let dataset = SyntheticDataset::new(&preset, cfg.seed);
    let seed = cfg.seed as i32;

    let make_executor = |_worker: usize, lane: usize| {
        let la = &lane_arts[lane];
        ArtifactExecutor::new(&la.init, la.fwd.clone(), seed)
    };
    // One fresh synthetic image per request (request id = batch index
    // of a single-row batch, so the stream is deterministic).
    let make_image = |_lane: usize, i: u64| dataset.batch(i, 1, 7).images;

    let report = run_lanes(
        &engine_opts(cfg),
        traffic,
        Arc::new(WallClock::new()),
        make_executor,
        make_image,
        None,
    )?;
    persist_trace(
        &cfg.trace,
        store.dir(),
        &lane_identities(cfg),
        &report.spans,
        report.trace_dropped,
    )?;
    Ok(report)
}

/// Persist one run's trace artifacts: the Chrome trace-event JSON to
/// `trace.trace_out` (when set), the [`ServiceSample`] calibration
/// records to `<dir>/service_samples.json`, and the refreshed
/// per-lane service-model fit to `<dir>/calibration.json` — next to
/// the compiled artifacts, where `[serve.planner] source =
/// "calibrated"` picks them up.  `lanes` maps each Execute span's
/// run-local lane index to its stable identity (see
/// [`lane_identities`]).
///
/// Both JSON files *merge* with what is already on disk rather than
/// clobbering it: samples append under a per-lane cap
/// ([`crate::trace::SERVICE_SAMPLE_CAP`], oldest dropped first), and
/// calibration entries replace only the lanes this run re-fitted —
/// short runs never erase another lane's history.  No-op when
/// tracing is off or no spans were recorded.
pub fn persist_trace(
    trace: &TraceConfig,
    dir: &std::path::Path,
    lanes: &[crate::trace::LaneId],
    spans: &[Span],
    dropped: u64,
) -> Result<()> {
    if !trace.enabled || spans.is_empty() {
        return Ok(());
    }
    if let Some(out) = &trace.trace_out {
        crate::trace::chrome::write_chrome_trace(
            std::path::Path::new(out),
            spans,
            dropped,
        )?;
        eprintln!("[mpx] trace: wrote {} spans to {out}", spans.len());
    }
    let samples = crate::trace::service_samples(spans, lanes);
    if samples.is_empty() {
        return Ok(());
    }
    let path = dir.join("service_samples.json");
    let existing = crate::trace::read_service_samples(&path)
        .unwrap_or_else(|e| {
            eprintln!("[mpx] trace: {e}; starting a fresh sample history");
            Vec::new()
        });
    let merged = crate::trace::merge_service_samples(
        existing,
        &samples,
        crate::trace::SERVICE_SAMPLE_CAP,
    );
    crate::trace::write_service_samples(&path, &merged)?;
    eprintln!(
        "[mpx] trace: {} service samples ({} new) in {}",
        merged.len(),
        samples.len(),
        path.display()
    );

    // Re-fit from the merged history: more batches per (lane, bucket)
    // than any single run provides, and bit-deterministic for a given
    // history.  Lanes the fit guard rejects keep their previous
    // calibration entry (merge, don't clobber).
    let fresh = Calibration::fit(&merged);
    if !fresh.is_empty() {
        let cal_path = dir.join(calibrate::CALIBRATION_FILE);
        let old = Calibration::read(&cal_path).unwrap_or_else(|e| {
            eprintln!("[mpx] calibrate: {e}; rebuilding from samples");
            Calibration::default()
        });
        let cal = old.merge(fresh);
        cal.write(&cal_path)?;
        eprintln!(
            "[mpx] calibrate: fitted {} lane(s) into {}",
            cal.lanes.len(),
            cal_path.display()
        );
    }
    Ok(())
}

/// Compiled artifacts backing one serving lane.
struct LaneArtifacts {
    init: Arc<Artifact>,
    fwd: Vec<(usize, Arc<Artifact>)>,
}

/// Lane setup shared by every artifact-backed serve entry point.
struct PreparedLanes {
    lane_cfgs: Vec<LaneConfig>,
    specs: Vec<LaneSpec>,
    arts: Vec<LaneArtifacts>,
    /// Per lane: every bucket size with a compiled forward artifact —
    /// the hard ceiling a live replan can adopt without recompiling.
    compiled: Vec<Vec<usize>>,
}

/// Discover/load the forward + init artifacts for every configured
/// lane and build its [`LaneSpec`] (planned buckets + flush timeout
/// when the planner is on, the discovered set otherwise).  Shared by
/// [`run_with_artifacts`] (synthetic loadgen) and
/// [`run_transport_with_artifacts`] (network serving) so both paths
/// serve exactly the same plan with the same hard errors.
fn prepare_lanes(
    store: &mut ArtifactStore,
    cfg: &ServeConfig,
) -> Result<PreparedLanes> {
    let lane_cfgs = cfg.lane_configs();
    let plan = if cfg.use_planner() {
        let plan = plan_for_config(cfg)?;
        if !plan.is_feasible() {
            for l in &plan.lanes {
                if let PlanVerdict::Infeasible { reason } = &l.verdict {
                    eprintln!("[plan] lane {}: INFEASIBLE — {reason}", l.name);
                }
            }
            bail!(
                "serve: planner found no feasible bucket plan — relax the \
                 lane deadlines, add workers, or raise the starved lanes' \
                 weights (with [serve.lanes.*] tables the planner is always \
                 on; to serve unplanned, remove the lane tables)"
            );
        }
        Some(plan)
    } else {
        None
    };

    let mut lane_arts = Vec::new();
    let mut specs = Vec::new();
    let mut compiled = Vec::new();
    for (i, lc) in lane_cfgs.iter().enumerate() {
        let available = discover_buckets(store, cfg, lc.precision);
        if available.is_empty() {
            bail!(
                "no forward artifacts for model {} precision {} (expected \
                 e.g. {} in {}) — run `make artifacts`",
                cfg.model,
                lc.precision.tag(),
                cfg.fwd_artifact_for(lc.precision, cfg.max_batch),
                store.dir().display()
            );
        }
        let (buckets, flush) = match &plan {
            Some(plan) => {
                let lp = &plan.lanes[i];
                let missing =
                    missing_planned_artifacts(store, cfg, lc.precision, lp);
                // Serving a subset of the plan would silently void its
                // capacity/latency guarantees — fail as loudly as an
                // infeasible plan does, and say what to compile.
                if !missing.is_empty() {
                    bail!(
                        "serve: lane {}: planned buckets {:?} are not \
                         AOT-compiled (e.g. {} is missing) — run `make \
                         artifacts` for them (`mpx serve --plan` lists the \
                         full work list); the discovered set {:?} can only \
                         be served unplanned (no [serve.lanes.*] tables and \
                         [serve.planner] enabled = false)",
                        lc.name,
                        missing,
                        cfg.fwd_artifact_for(lc.precision, missing[0]),
                        available,
                    );
                }
                (lp.buckets.clone(), lp.flush_timeout)
            }
            None => (available.clone(), cfg.flush_timeout()),
        };
        // Load every *discovered* bucket artifact, not just the
        // planned subset: executors index by exact bucket size, and a
        // live replan may adopt any compiled bucket — the loaded set
        // is the hard ceiling of what `adopt_plan` can switch to.
        let fwd = available
            .iter()
            .map(|&b| {
                Ok((b, store.load(&cfg.fwd_artifact_for(lc.precision, b))?))
            })
            .collect::<Result<Vec<_>>>()?;
        let init = store.load(&cfg.init_artifact_for(lc.precision))?;
        specs.push(LaneSpec {
            name: format!("{}/{}", cfg.model, lc.name),
            weight: lc.weight,
            batcher: BatcherConfig::new(buckets, flush)?,
            queue_capacity: cfg.queue_capacity,
            deadline: lc.deadline(),
        });
        lane_arts.push(LaneArtifacts { init, fwd });
        compiled.push(available);
    }
    Ok(PreparedLanes { lane_cfgs, specs, arts: lane_arts, compiled })
}

/// The network serving path behind `mpx serve --listen`: the same
/// artifact discovery/planning as [`run_with_artifacts`], but instead
/// of a synthetic load generator the lanes are fed by the
/// [`transport`] HTTP server, which streams each completion back to
/// its caller and drains gracefully on SIGINT.  Blocks until the
/// drain completes; returns the transport-side report.
pub fn run_transport_with_artifacts(
    store: &mut ArtifactStore,
    cfg: &ServeConfig,
) -> Result<TransportReport> {
    cfg.validate()?;
    let prepared = prepare_lanes(store, cfg)?;
    let preset = model_preset(&cfg.model)?;
    let image_elems =
        preset.image_size * preset.image_size * preset.channels;
    let seed = cfg.seed as i32;

    transport::install_sigint();
    let mut server = transport::Server::bind(&cfg.transport)?;
    server.set_trace(cfg.trace.clone());
    // Autoscale rides the transport arrival path: admissions feed
    // `Scheduler::poll_autoscale` from the reactor, so the pool
    // starts at `min_workers` and grows with real traffic.
    server.set_autoscale(autoscale_policy(cfg));
    eprintln!(
        "[mpx] serve: listening on http://{} | {} lanes ({}), {} workers | \
         POST /v1/infer, GET /healthz, GET /metrics{} | Ctrl-C drains and \
         exits",
        server.local_addr(),
        prepared.specs.len(),
        prepared
            .specs
            .iter()
            .map(|s| s.name.as_str())
            .collect::<Vec<_>>()
            .join(", "),
        cfg.workers,
        if cfg.trace.enabled { ", GET /debug/trace" } else { "" },
    );

    // Close the planner loop: when the planner chose the buckets,
    // watch the measured arrival rates / deadline pressure and replan
    // live against the resolved (config or calibrated) service
    // models, constrained to the compiled bucket sets.
    let (models, _) = lane_service_models(cfg)?;
    if cfg.use_planner() {
        let spec = ReplanSpec {
            drift: DriftConfig::default(),
            planner: planner_config(cfg),
            models: models.clone(),
            compiled: prepared.compiled.clone(),
        };
        server.set_replan(ReplanDriver::new(
            spec,
            lane_profiles(cfg),
            Duration::ZERO,
        ));
    }
    server.set_service_models(
        models
            .iter()
            .map(|m| {
                (m.overhead.as_micros() as u64, m.per_row.as_micros() as u64)
            })
            .collect(),
    );

    let lane_arts = prepared.arts;
    let make_executor = |_worker: usize, lane: usize| {
        let la = &lane_arts[lane];
        ArtifactExecutor::new(&la.init, la.fwd.clone(), seed)
    };
    let report = server.run(
        prepared.specs,
        cfg.workers,
        cfg.policy,
        image_elems,
        make_executor,
    )?;
    persist_trace(
        &cfg.trace,
        store.dir(),
        &lane_identities(cfg),
        &report.spans,
        report.trace_dropped,
    )?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LaneConfig, Precision};

    fn lane(name: &str, rate: f64) -> LaneConfig {
        LaneConfig { rate, ..LaneConfig::named(name, Precision::MixedF16) }
    }

    #[test]
    fn split_requests_follows_the_rates() {
        // 3:1 rates → 3:1 requests, remainder to lane 0.
        let lanes = [lane("a", 300.0), lane("b", 100.0)];
        assert_eq!(split_requests(100, &lanes), vec![75, 25]);
        assert_eq!(split_requests(101, &lanes), vec![76, 25]);
        // Zero-rate lanes get nothing while others offer load — even
        // the rounding remainder lands on a *rated* lane, wherever a
        // zero-rate lane sorts.
        let lanes = [lane("a", 50.0), lane("idle", 0.0)];
        assert_eq!(split_requests(10, &lanes), vec![10, 0]);
        let lanes = [lane("idle", 0.0), lane("chat", 30.0), lane("web", 70.0)];
        assert_eq!(split_requests(101, &lanes), vec![0, 31, 70]);
        // All back-to-back: even split, lane 0 absorbs the remainder.
        let lanes = [lane("a", 0.0), lane("b", 0.0), lane("c", 0.0)];
        assert_eq!(split_requests(10, &lanes), vec![4, 3, 3]);
        assert_eq!(split_requests(0, &lanes), vec![0, 0, 0]);
        assert!(split_requests(5, &[]).is_empty());
        // Conservation, always.
        let lanes = [lane("a", 7.0), lane("b", 11.0), lane("c", 13.0)];
        for total in [0u64, 1, 2, 97, 1000] {
            assert_eq!(
                split_requests(total, &lanes).iter().sum::<u64>(),
                total
            );
        }
    }

    #[test]
    fn split_requests_conserves_and_respects_zero_rates() {
        // Property sweep under a deterministic LCG: for any mix of
        // rated and zero-rate lanes, (1) the split sums to the total,
        // (2) zero-rate lanes get nothing while any lane is rated,
        // (3) every rated lane except the first gets exactly its
        // floored proportional share — the remainder lands on the
        // first *rated* lane and nowhere else.
        let mut state = 0x2545_F491_4F6C_DD1Du64;
        let mut next = move || {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            state >> 33
        };
        for _ in 0..500 {
            let n = (next() % 6 + 1) as usize;
            let total = next() % 10_000;
            let lanes: Vec<LaneConfig> = (0..n)
                .map(|i| {
                    let rate = if next() % 3 == 0 {
                        0.0
                    } else {
                        (next() % 997 + 1) as f64 / 7.0
                    };
                    lane(&format!("l{i}"), rate)
                })
                .collect();
            let out = split_requests(total, &lanes);
            assert_eq!(out.len(), n);
            assert_eq!(out.iter().sum::<u64>(), total, "lanes {lanes:?}");
            let sum: f64 = lanes.iter().map(|l| l.rate.max(0.0)).sum();
            let Some(first) = lanes.iter().position(|l| l.rate > 0.0) else {
                continue; // all back-to-back: covered by the exact test
            };
            for (i, l) in lanes.iter().enumerate() {
                let floor_share =
                    (total as f64 * l.rate.max(0.0) / sum).floor() as u64;
                if l.rate <= 0.0 {
                    assert_eq!(out[i], 0, "zero-rate lane {i} offered load");
                } else if i == first {
                    assert!(out[i] >= floor_share);
                } else {
                    assert_eq!(out[i], floor_share, "remainder leaked to {i}");
                }
            }
        }
    }

    #[test]
    fn persist_trace_merges_samples_and_calibration_across_runs() {
        // Regression: persist_trace used to rewrite
        // service_samples.json wholesale, so each run erased every
        // other lane's history (and with it the calibration).  Two
        // runs against the same directory must *accumulate* samples
        // and keep both lanes' fits.
        use crate::trace::{LaneId, Span, SpanKind, TraceConfig};
        let dir = std::env::temp_dir().join("mpx_persist_trace_merge_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let us = Duration::from_micros;
        let exec = |seq: u64, bucket: u64, dur_us: u64| Span {
            kind: SpanKind::Execute,
            start: us(seq * 10_000),
            end: us(seq * 10_000 + dur_us),
            seq,
            a: 0,
            b: bucket,
            c: bucket,
        };
        // Ten executes on an exact linear model 300 + 130·rows.
        let spans_a: Vec<Span> = (0..10)
            .map(|i| {
                if i % 2 == 0 { exec(i, 1, 430) } else { exec(i, 8, 1340) }
            })
            .collect();
        let trace = TraceConfig { enabled: true, ..TraceConfig::default() };
        let lanes_a = [LaneId::new("m/chat", "mixed_f16")];
        persist_trace(&trace, &dir, &lanes_a, &spans_a, 0).unwrap();

        let sample_path = dir.join("service_samples.json");
        let after_a =
            crate::trace::read_service_samples(&sample_path).unwrap();
        assert_eq!(after_a.len(), 10);
        let cal_path = dir.join(calibrate::CALIBRATION_FILE);
        let cal_a = Calibration::read(&cal_path).unwrap();
        let fit = cal_a.get("m/chat", "mixed_f16").expect("fitted lane");
        assert_eq!((fit.overhead_us, fit.per_row_us), (300, 130));

        // A second run exercising a *different* lane appends its
        // samples and leaves the first lane's history and fit intact.
        let spans_b: Vec<Span> = (0..10)
            .map(|i| {
                if i % 2 == 0 { exec(i, 1, 800) } else { exec(i, 8, 2200) }
            })
            .collect();
        let lanes_b = [LaneId::new("m/bulk", "fp32")];
        persist_trace(&trace, &dir, &lanes_b, &spans_b, 0).unwrap();
        let merged =
            crate::trace::read_service_samples(&sample_path).unwrap();
        assert_eq!(merged.len(), 20);
        assert_eq!(merged.iter().filter(|s| s.lane == "m/chat").count(), 10);
        let cal = Calibration::read(&cal_path).unwrap();
        let kept = cal.get("m/chat", "mixed_f16").expect("merge clobbered");
        assert_eq!((kept.overhead_us, kept.per_row_us), (300, 130));
        let bulk = cal.get("m/bulk", "fp32").expect("new lane unfitted");
        assert_eq!((bulk.overhead_us, bulk.per_row_us), (600, 200));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn plan_for_config_uses_the_lane_tables() {
        let cfg = ServeConfig {
            max_batch: 8,
            workers: 2,
            lanes: vec![
                LaneConfig {
                    rate: 40.0,
                    deadline_ms: 30,
                    ..LaneConfig::named("chat", Precision::MixedF16)
                },
                LaneConfig {
                    deadline_ms: 1000,
                    ..LaneConfig::named("bulk", Precision::Fp32)
                },
            ],
            ..ServeConfig::default()
        };
        assert!(cfg.use_planner());
        let plan = plan_for_config(&cfg).unwrap();
        assert!(plan.is_feasible());
        assert_eq!(plan.lanes.len(), 2);
        assert_eq!(plan.lanes[0].name, "chat");
        // Sparse interactive traffic needs bucket 1; saturated bulk
        // runs one full bucket.
        assert!(plan.lanes[0].buckets.contains(&1));
        assert_eq!(plan.lanes[1].buckets, vec![8]);
        // Candidates follow max_batch, so nothing exceeds it.
        assert!(plan.all_buckets().iter().all(|&b| b <= 8));
    }

    #[test]
    fn plan_for_config_rejects_invalid_configs() {
        let cfg = ServeConfig {
            lanes: vec![LaneConfig {
                weight: 0,
                ..LaneConfig::named("a", Precision::Fp32)
            }],
            ..ServeConfig::default()
        };
        assert!(plan_for_config(&cfg).is_err());
    }
}
