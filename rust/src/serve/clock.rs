//! Time source abstraction: all serve-path timing flows through
//! [`Clock`] so the scheduler, queue, batcher, and load generator are
//! testable without real sleeps.
//!
//! Timestamps are [`Duration`]s since the clock's epoch (its creation
//! instant), not [`std::time::Instant`]s — a plain monotonic number
//! that a virtual clock can fabricate.  Two implementations:
//!
//! * [`WallClock`] — production: `now` is the elapsed real time since
//!   construction, `sleep_until` is `std::thread::sleep`.
//! * [`VirtualClock`] — tests and the simulation harness
//!   ([`crate::serve::sched::simulate`]): time only moves when the
//!   driver calls [`VirtualClock::set`]/[`VirtualClock::advance`], so
//!   flush timeouts, deadline misses, and autoscaling decisions are
//!   exactly reproducible with zero wall-clock cost.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A monotonic time source.  `now` is the time since the clock's
/// epoch; `sleep_until` blocks the calling thread until that instant
/// (returning immediately when it is already past).
pub trait Clock: Send + Sync {
    fn now(&self) -> Duration;
    fn sleep_until(&self, deadline: Duration);
}

/// Real time, anchored at construction.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }

    fn sleep_until(&self, deadline: Duration) {
        let now = self.epoch.elapsed();
        if deadline > now {
            std::thread::sleep(deadline - now);
        }
    }
}

/// Simulated time: starts at zero and moves only when told to.
///
/// `sleep_until` parks the caller until another thread advances the
/// clock past the deadline — but the single-threaded simulation
/// harness never sleeps at all; it calls [`VirtualClock::set`] as it
/// replays events in timestamp order.
pub struct VirtualClock {
    now: Mutex<Duration>,
    tick: Condvar,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now: Mutex::new(Duration::ZERO), tick: Condvar::new() }
    }

    /// Jump to an absolute time.  Panics when asked to move backwards
    /// — a simulation replaying events out of order is a bug.
    pub fn set(&self, to: Duration) {
        let mut now = self.now.lock().unwrap();
        assert!(
            to >= *now,
            "virtual clock moved backwards: {now:?} -> {to:?}"
        );
        *now = to;
        drop(now);
        self.tick.notify_all();
    }

    /// Move time forward by `by`.
    pub fn advance(&self, by: Duration) {
        let mut now = self.now.lock().unwrap();
        *now += by;
        drop(now);
        self.tick.notify_all();
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        *self.now.lock().unwrap()
    }

    fn sleep_until(&self, deadline: Duration) {
        let mut now = self.now.lock().unwrap();
        while *now < deadline {
            now = self.tick.wait(now).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotone() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn wall_clock_sleep_until_past_returns_immediately() {
        let c = WallClock::new();
        c.sleep_until(Duration::ZERO); // epoch is already past
    }

    #[test]
    fn virtual_clock_starts_at_zero_and_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(5));
        assert_eq!(c.now(), Duration::from_millis(5));
        c.set(Duration::from_millis(9));
        assert_eq!(c.now(), Duration::from_millis(9));
        c.set(Duration::from_millis(9)); // equal is fine
    }

    #[test]
    #[should_panic(expected = "moved backwards")]
    fn virtual_clock_rejects_time_travel() {
        let c = VirtualClock::new();
        c.set(Duration::from_millis(10));
        c.set(Duration::from_millis(3));
    }

    #[test]
    fn virtual_sleep_until_wakes_on_advance() {
        let c = std::sync::Arc::new(VirtualClock::new());
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.sleep_until(Duration::from_millis(4));
            c2.now()
        });
        // Advance in two hops; the sleeper must survive the first.
        c.advance(Duration::from_millis(2));
        c.advance(Duration::from_millis(2));
        assert_eq!(h.join().unwrap(), Duration::from_millis(4));
    }
}
