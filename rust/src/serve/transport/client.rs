//! Std-only HTTP client for the serve transport — the counterpart of
//! the server in [`super`], shared by the network load generator
//! ([`drive`]) and the integration tests.
//!
//! Two modes, with explicit connect/read timeouts on both:
//!
//! * [`Client`] — one request per connection (`Connection: close`).
//!   [`Client::open`] exposes the raw streamed response (status,
//!   headers, then chunk-at-a-time) so tests can observe — or
//!   abandon — a stream mid-flight; [`Client::infer`] is the
//!   convenient "send an image, get the logits" wrapper.
//! * [`Connection`] — a persistent keep-alive connection
//!   ([`Client::connect_keep_alive`]).  [`Connection::request`]
//!   round-trips on the reused socket; [`Connection::send`] followed
//!   by [`Connection::read_response`] pipelines — several requests on
//!   the wire before the first response is read, answered strictly in
//!   order by the server's reactor.

use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::LatencyHistogram;
use crate::serve::clock::{Clock, WallClock};
use crate::serve::loadgen;
use crate::serve::transport::http;
use crate::util::json::Json;

/// One parsed inference result (the stream's terminal data line).
#[derive(Debug, Clone)]
pub struct InferReply {
    pub id: u64,
    pub lane: String,
    /// Server-side admission→completion latency.
    pub latency: Duration,
    pub missed_deadline: bool,
    /// Overflow signal: false when any logit came back non-finite
    /// (serialized as `null` in the JSON).
    pub finite: bool,
    /// Logits row; non-finite entries surface as `f32::NAN`.
    pub logits: Vec<f32>,
}

/// A live streamed response: headers are in; chunks arrive as the
/// server writes them.  Dropping it closes the connection (which is
/// how the disconnect tests abandon a stream mid-flight).
pub struct ResponseStream {
    // Owns the write half; reader owns a cloned read half.
    #[allow(dead_code)]
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    pub status: u16,
    pub headers: Vec<(String, String)>,
    chunked: bool,
    content_length: Option<usize>,
    done: bool,
}

impl ResponseStream {
    pub fn header(&self, name: &str) -> Option<&str> {
        http::header(&self.headers, name)
    }

    /// Next body chunk; `None` once the body is complete.  For
    /// non-chunked responses the whole body is returned as one chunk.
    pub fn next_chunk(&mut self) -> Result<Option<Vec<u8>>> {
        if self.done {
            return Ok(None);
        }
        if self.chunked {
            let chunk = http::read_chunk(&mut self.reader)
                .context("read response chunk")?;
            if chunk.is_none() {
                self.done = true;
            }
            Ok(chunk)
        } else {
            self.done = true;
            let len = self.content_length.unwrap_or(0);
            if len == 0 {
                return Ok(None);
            }
            let body = http::read_sized_body(&mut self.reader, len)
                .context("read response body")?;
            Ok(Some(body))
        }
    }
}

/// A fully-read response (every chunk drained).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    /// Body chunks in arrival order (one entry for sized bodies).
    pub chunks: Vec<Vec<u8>>,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        http::header(&self.headers, name)
    }

    /// All chunks concatenated.
    pub fn body(&self) -> Vec<u8> {
        self.chunks.concat()
    }

    pub fn body_string(&self) -> String {
        String::from_utf8_lossy(&self.body()).into_owned()
    }
}

/// Client for one server address.
pub struct Client {
    addr: String,
    timeout: Duration,
}

impl Client {
    pub fn new(addr: impl Into<String>) -> Client {
        Client { addr: addr.into(), timeout: Duration::from_secs(10) }
    }

    /// Override the connect/read timeout (default 10 s).
    pub fn with_timeout(mut self, timeout: Duration) -> Client {
        self.timeout = timeout;
        self
    }

    fn connect(&self) -> Result<TcpStream> {
        let addrs: Vec<_> = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolve {}", self.addr))?
            .collect();
        let mut last = None;
        for addr in addrs {
            match TcpStream::connect_timeout(&addr, self.timeout) {
                Ok(s) => {
                    s.set_read_timeout(Some(self.timeout))?;
                    s.set_write_timeout(Some(self.timeout))?;
                    s.set_nodelay(true)?;
                    return Ok(s);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(match last {
            Some(e) => anyhow::Error::from(e)
                .context(format!("connect {}", self.addr)),
            None => anyhow!("{}: no addresses resolved", self.addr),
        })
    }

    /// Send one request and return the response with headers parsed
    /// and the body still streaming.
    pub fn open(
        &self,
        method: &str,
        path: &str,
        content_type: &str,
        extra: &[(&str, &str)],
        body: &[u8],
    ) -> Result<ResponseStream> {
        let mut stream = self.connect()?;
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\n",
            self.addr
        );
        if !body.is_empty() || method == "POST" {
            head.push_str(&format!(
                "Content-Type: {content_type}\r\nContent-Length: {}\r\n",
                body.len()
            ));
        }
        for (name, value) in extra {
            head.push_str(&format!("{name}: {value}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.write_all(body)?;
        stream.flush()?;

        let read_half = stream.try_clone().context("clone read half")?;
        let mut reader = BufReader::new(read_half);
        let head = http::read_response_head(&mut reader)
            .context("read response head")?;
        let chunked = head.is_chunked();
        let content_length = head
            .header("content-length")
            .and_then(|v| v.trim().parse::<usize>().ok());
        Ok(ResponseStream {
            stream,
            reader,
            status: head.status,
            headers: head.headers,
            chunked,
            content_length,
            done: false,
        })
    }

    /// Send one request and drain the whole response.
    pub fn request(
        &self,
        method: &str,
        path: &str,
        content_type: &str,
        extra: &[(&str, &str)],
        body: &[u8],
    ) -> Result<Response> {
        let mut rs = self.open(method, path, content_type, extra, body)?;
        let mut chunks = Vec::new();
        while let Some(chunk) = rs.next_chunk()? {
            chunks.push(chunk);
        }
        Ok(Response { status: rs.status, headers: rs.headers, chunks })
    }

    pub fn healthz(&self) -> Result<Response> {
        self.request("GET", "/healthz", "application/json", &[], &[])
    }

    /// The Prometheus text page.
    pub fn metrics(&self) -> Result<String> {
        let resp =
            self.request("GET", "/metrics", "text/plain", &[], &[])?;
        if resp.status != 200 {
            bail!("GET /metrics: status {}", resp.status);
        }
        Ok(resp.body_string())
    }

    /// The span dump (`GET /debug/trace`): Chrome trace-event JSON,
    /// 404 when the server runs with tracing disabled.
    pub fn debug_trace(&self) -> Result<String> {
        let resp = self
            .request("GET", "/debug/trace", "application/json", &[], &[])?;
        if resp.status != 200 {
            bail!("GET /debug/trace: status {}", resp.status);
        }
        Ok(resp.body_string())
    }

    /// JSON inference: stream until the result line arrives.  Non-200
    /// statuses and in-stream errors become `Err` (the status code is
    /// in the message; use [`Client::request`] when a test needs the
    /// raw status/headers).
    pub fn infer(&self, lane: &str, image: &[f32]) -> Result<InferReply> {
        let body = infer_body_json(lane, image);
        let resp = self.request(
            "POST",
            "/v1/infer",
            "application/json",
            &[],
            body.as_bytes(),
        )?;
        reply_from_response(&resp)
    }

    /// Binary inference: raw little-endian f32 rows, lane in a header.
    pub fn infer_binary(&self, lane: &str, image: &[f32]) -> Result<InferReply> {
        let mut body = Vec::with_capacity(image.len() * 4);
        for v in image {
            body.extend_from_slice(&v.to_le_bytes());
        }
        let resp = self.request(
            "POST",
            "/v1/infer",
            "application/octet-stream",
            &[("X-Mpx-Lane", lane)],
            &body,
        )?;
        reply_from_response(&resp)
    }

    /// Open a persistent keep-alive connection to the server.
    pub fn connect_keep_alive(&self) -> Result<Connection> {
        let stream = self.connect()?;
        let read_half = stream.try_clone().context("clone read half")?;
        Ok(Connection {
            addr: self.addr.clone(),
            stream,
            reader: BufReader::new(read_half),
        })
    }
}

/// A persistent HTTP/1.1 keep-alive connection.  Requests reuse one
/// socket; [`send`](Connection::send) without an immediate
/// [`read_response`](Connection::read_response) pipelines.  Any I/O
/// or framing error poisons the connection — drop it and
/// [`Client::connect_keep_alive`] again.
pub struct Connection {
    addr: String,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Connection {
    /// Write one request, keeping the connection open for more.
    /// Responses to pipelined sends arrive strictly in send order.
    pub fn send(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        extra: &[(&str, &str)],
        body: &[u8],
    ) -> Result<()> {
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\n",
            self.addr
        );
        if !body.is_empty() || method == "POST" {
            head.push_str(&format!(
                "Content-Type: {content_type}\r\nContent-Length: {}\r\n",
                body.len()
            ));
        }
        for (name, value) in extra {
            head.push_str(&format!("{name}: {value}\r\n"));
        }
        head.push_str("\r\n");
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Read the next complete response off the connection (the
    /// earliest [`send`](Connection::send) not yet answered).
    pub fn read_response(&mut self) -> Result<Response> {
        let head = http::read_response_head(&mut self.reader)
            .context("read response head")?;
        let mut chunks = Vec::new();
        if head.is_chunked() {
            while let Some(chunk) = http::read_chunk(&mut self.reader)
                .context("read response chunk")?
            {
                chunks.push(chunk);
            }
        } else {
            let len = head
                .header("content-length")
                .and_then(|v| v.trim().parse::<usize>().ok())
                .unwrap_or(0);
            if len > 0 {
                chunks.push(
                    http::read_sized_body(&mut self.reader, len)
                        .context("read response body")?,
                );
            }
        }
        Ok(Response {
            status: head.status,
            headers: head.headers,
            chunks,
        })
    }

    /// One round trip on the reused socket.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        extra: &[(&str, &str)],
        body: &[u8],
    ) -> Result<Response> {
        self.send(method, path, content_type, extra, body)?;
        self.read_response()
    }

    /// JSON inference on the reused socket: send, then stream until
    /// the result line.
    pub fn infer(&mut self, lane: &str, image: &[f32]) -> Result<InferReply> {
        let body = infer_body_json(lane, image);
        let resp = self.request(
            "POST",
            "/v1/infer",
            "application/json",
            &[],
            body.as_bytes(),
        )?;
        reply_from_response(&resp)
    }
}

/// The JSON request body [`Client::infer`] sends.
pub fn infer_body_json(lane: &str, image: &[f32]) -> String {
    use std::fmt::Write;
    let mut s = String::with_capacity(32 + image.len() * 12);
    s.push_str("{\"lane\":");
    crate::util::json::write_escaped(lane, &mut s);
    s.push_str(",\"image\":[");
    for (i, v) in image.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{v}");
    }
    s.push_str("]}");
    s
}

fn reply_from_response(resp: &Response) -> Result<InferReply> {
    if resp.status != 200 {
        bail!(
            "infer: status {}: {}",
            resp.status,
            resp.body_string().trim()
        );
    }
    // Chunks are ndjson lines: ack first, then the result.
    for chunk in &resp.chunks {
        let text = std::str::from_utf8(chunk).context("non-utf8 chunk")?;
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let doc = Json::parse(line.trim())
                .map_err(|e| anyhow!("bad result line {line:?}: {e}"))?;
            if let Some(err) = doc.get("error").and_then(Json::as_str) {
                bail!("infer: server error: {err}");
            }
            if doc.get("logits").is_none() {
                continue; // the queued ack
            }
            return parse_reply(&doc);
        }
    }
    bail!("infer: stream ended without a result line")
}

fn parse_reply(doc: &Json) -> Result<InferReply> {
    let id = doc
        .get("id")
        .and_then(Json::as_i64)
        .context("result missing id")? as u64;
    let lane = doc
        .get("lane")
        .and_then(Json::as_str)
        .context("result missing lane")?
        .to_string();
    let latency_us = doc
        .get("latency_us")
        .and_then(Json::as_i64)
        .context("result missing latency_us")? as u64;
    let missed_deadline = doc
        .get("missed_deadline")
        .and_then(Json::as_bool)
        .unwrap_or(false);
    let finite =
        doc.get("finite").and_then(Json::as_bool).unwrap_or(true);
    let logits = doc
        .get("logits")
        .and_then(Json::as_arr)
        .context("result missing logits")?
        .iter()
        .map(|v| v.as_f64().map(|x| x as f32).unwrap_or(f32::NAN))
        .collect();
    Ok(InferReply {
        id,
        lane,
        latency: Duration::from_micros(latency_us),
        missed_deadline,
        finite,
        logits,
    })
}

// ---------------------------------------------------------------------------
// Network load generator
// ---------------------------------------------------------------------------

/// What [`drive`] observed, from the client's side of the wire.
#[derive(Debug)]
pub struct DriveReport {
    pub offered: u64,
    pub completed: u64,
    /// `429` responses.
    pub rejected: u64,
    /// Everything else that was not a streamed result.
    pub errors: u64,
    /// Client-observed round-trip latency (connect → result line).
    pub latency: LatencyHistogram,
    /// Responses whose logits contained a non-finite value.
    pub nonfinite: u64,
}

impl DriveReport {
    fn merge(&mut self, other: DriveReport) {
        self.offered += other.offered;
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.errors += other.errors;
        self.nonfinite += other.nonfinite;
        self.latency.merge(&other.latency);
    }
}

/// Drive a live transport server with the same deterministic Poisson
/// arrival process the in-process engine benchmarks use
/// ([`loadgen::poisson_offsets`]): `n` requests to `lane` at
/// `rate_per_s` (≤ 0 = back-to-back), `make_image(i)` producing each
/// payload, spread over `concurrency` sender threads that share one
/// paced timeline.
pub fn drive<G>(
    addr: &str,
    lane: &str,
    n: u64,
    rate_per_s: f64,
    seed: u64,
    concurrency: usize,
    make_image: G,
) -> DriveReport
where
    G: Fn(u64) -> Vec<f32> + Sync,
{
    let offsets = loadgen::poisson_offsets(n, rate_per_s, seed);
    let clock = WallClock::new();
    let next = AtomicUsize::new(0);
    let nonfinite = AtomicU64::new(0);
    let concurrency = concurrency.max(1);
    let start = clock.now();

    let mut report = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..concurrency)
            .map(|_| {
                let client = Client::new(addr.to_string());
                let next = &next;
                let offsets = &offsets;
                let clock = &clock;
                let make_image = &make_image;
                let nonfinite = &nonfinite;
                scope.spawn(move || {
                    let mut rep = DriveReport {
                        offered: 0,
                        completed: 0,
                        rejected: 0,
                        errors: 0,
                        latency: LatencyHistogram::new(),
                        nonfinite: 0,
                    };
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= offsets.len() {
                            break;
                        }
                        loadgen::pace(clock, start, offsets[i]);
                        rep.offered += 1;
                        let t0 = clock.now();
                        let body = infer_body_json(
                            lane,
                            &make_image(i as u64),
                        );
                        match client.request(
                            "POST",
                            "/v1/infer",
                            "application/json",
                            &[],
                            body.as_bytes(),
                        ) {
                            Ok(resp) if resp.status == 200 => {
                                match reply_from_response(&resp) {
                                    Ok(reply) => {
                                        rep.completed += 1;
                                        rep.latency.record(
                                            clock
                                                .now()
                                                .saturating_sub(t0),
                                        );
                                        if !reply.finite {
                                            nonfinite.fetch_add(
                                                1,
                                                Ordering::Relaxed,
                                            );
                                        }
                                    }
                                    Err(_) => rep.errors += 1,
                                }
                            }
                            Ok(resp) if resp.status == 429 => {
                                rep.rejected += 1;
                            }
                            Ok(_) | Err(_) => rep.errors += 1,
                        }
                    }
                    rep
                })
            })
            .collect();
        let mut total = DriveReport {
            offered: 0,
            completed: 0,
            rejected: 0,
            errors: 0,
            latency: LatencyHistogram::new(),
            nonfinite: 0,
        };
        for h in handles {
            total.merge(h.join().expect("drive sender panicked"));
        }
        total
    });
    report.nonfinite = nonfinite.load(Ordering::Relaxed);
    report
}
