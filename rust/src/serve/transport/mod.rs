//! Network transport for `mpx serve` — a dependency-light
//! event-driven HTTP/1.1 server that turns the in-process serving
//! engine ([`crate::serve`]) into a real service, plus the std-only
//! [`client`] the load generator and the integration tests drive it
//! with.
//!
//! ```text
//!   clients ──keep-alive / pipelined──▶ reactor (one poll loop)
//!                                        │ accept ▸ read ▸ parse
//!                                        │ route (lane) ▸ submit
//!                                        ▼
//!                            Scheduler::submit (per-lane queue)
//!          admitted │ full │ closed │ unknown │ malformed
//!            200    │ 429  │  503   │  404    │   400
//!          chunked  ▲
//!          stream   │ CompletionFn (worker thread) pushes the
//!                   │ outcome and tugs the wake pipe; the reactor
//!                   │ serializes + flushes on its own thread
//! ```
//!
//! A single reactor thread owns every connection: nonblocking
//! sockets multiplexed through [`reactor::poll_ready`] (raw
//! `poll(2)` FFI, the same always-linked-libc approach as
//! [`install_sigint`]).  Worker threads never touch a socket — a
//! completing batch pushes its [`Outcome`] onto a queue and tugs the
//! reactor's [`reactor::WakePipe`]; the reactor serializes and
//! flushes the chunk.  Thread count is `1 + workers`, independent of
//! the number of open connections.
//!
//! Semantics, mapped faithfully onto HTTP:
//!
//! * **Keep-alive and pipelining** — HTTP/1.1 connections are
//!   reusable by default (`Connection: close` / HTTP/1.0 opt out),
//!   and up to `max_pipelined` requests may be in flight per
//!   connection; responses are delivered strictly in request order.
//! * **Streaming, not polling** — an admitted request gets its
//!   response headers and a `queued` ack chunk immediately, then its
//!   result chunk the instant its batch completes (per-request
//!   [`Completion`] callbacks, chunked transfer encoding).  There is
//!   no batch-granularity blocking anywhere on the response path.
//! * **Admission control is the status code** — a full lane queue is
//!   `429 Too Many Requests` with `Retry-After` derived from that
//!   lane's (planner-chosen) flush timeout; a closed/draining lane is
//!   `503 Service Unavailable`; an unknown lane is `404`; an
//!   unparsable payload is `400`.
//! * **Whole-request deadlines** — `read_timeout_ms` bounds the gap
//!   between bytes mid-request and `request_deadline_ms` bounds the
//!   first-byte→complete-parse window; a trickling (slowloris)
//!   client is evicted with `408` instead of pinning anything.  An
//!   idle keep-alive connection is closed silently after
//!   `idle_timeout_ms`.
//! * **Autoscaling on arrivals** — admissions feed
//!   [`Scheduler::poll_autoscale`]; when the configured
//!   [`AutoscalePolicy`] asks for more workers the reactor spawns
//!   them right on the arrival path (the pool starts at
//!   `min_workers`).
//! * **Overflow accounting is per response** (Zhao et al., adaptive
//!   loss scaling: keep the numerics observable end-to-end): every
//!   result reports `finite` — whether the half-precision forward
//!   produced any non-finite logit — and `/metrics` exports the
//!   per-lane `nonfinite` counter next to the latency summaries.
//! * **Graceful drain** — shutdown (SIGINT via [`install_sigint`], or
//!   [`ServerHandle::shutdown`]) stops admitting (`503`), closes the
//!   lanes so workers flush everything queued, keeps serving
//!   `/healthz`+`/metrics`, and exits once every pending stream
//!   flushed or `drain_deadline_ms` passed — abandoned streams get an
//!   error chunk, and nothing leaks: the pending-stream count and
//!   the worker slots both drain to zero.
//!
//! Protocol decision, kept from the threaded transport: a FIN from
//! the client is treated as *abandonment*, even though TCP cannot
//! distinguish a full close from a half-close (`SHUT_WR`) of a
//! client still reading.  Clients of this transport must keep their
//! socket fully open until the result chunk arrives — [`client`]
//! does — and in exchange the server frees resources the moment a
//! caller hangs up.
//!
//! Everything here is std-only and runs without the `xla` feature:
//! `rust/tests/serve_transport.rs` drives real sockets (including a
//! many-connections soak and a slowloris eviction) against a stub
//! executor.

pub mod client;
pub mod http;
pub mod reactor;

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::raw::{c_int, c_short};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::TransportConfig;
use crate::metrics::{LatencyHistogram, NamedHistograms};
use crate::serve::batcher::SchedPolicy;
use crate::serve::calibrate::ReplanDriver;
use crate::serve::clock::{Clock, WallClock};
use crate::serve::queue::{QueueStats, Request};
use crate::serve::sched::{
    AutoscalePolicy, Completion, CompletionFn, LaneSpec, PoolCounters,
    ScaleOp, Scheduler,
};
use crate::serve::worker::{worker_loop, BatchExecutor, WorkerReport};
use crate::trace::{chrome, Span, SpanKind, TraceConfig, Tracer};
use crate::util::human_duration;
use crate::util::json::{write_escaped, Json};

use self::reactor::{poll_ready, PollFd, WakePipe, POLLIN, POLLOUT};

// ---------------------------------------------------------------------------
// SIGINT → graceful drain
// ---------------------------------------------------------------------------

static SIGINT_FLAG: AtomicBool = AtomicBool::new(false);

/// Install a process-wide SIGINT handler that requests a graceful
/// drain of every running [`Server`] (stop accepting new inference,
/// flush the lanes, then exit).  Pure-std via the libc `signal`
/// symbol that is always linked on unix; a no-op elsewhere.  The
/// handler only sets an atomic flag — the reactor polls it (and a
/// signal interrupting `poll(2)` reports as zero ready descriptors,
/// so the flag is observed at once).
#[cfg(unix)]
pub fn install_sigint() {
    extern "C" fn on_sigint(_sig: i32) {
        SIGINT_FLAG.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }
    unsafe {
        signal(2 /* SIGINT */, on_sigint);
    }
}

#[cfg(not(unix))]
pub fn install_sigint() {}

/// Whether SIGINT has been received since [`install_sigint`].
pub fn sigint_requested() -> bool {
    SIGINT_FLAG.load(Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// Shared server state
// ---------------------------------------------------------------------------

/// A completed batch entry queued for the reactor: everything needed
/// to serialize the result chunk on the reactor thread.
struct Outcome {
    id: u64,
    lane: usize,
    latency: Duration,
    missed_deadline: bool,
    finite: bool,
    logits: Vec<f32>,
}

/// Transport-level counters.  Plain totals since server start; the
/// per-lane engine accounting lives in the queue stats and
/// [`StreamTally`]s.
#[derive(Default)]
struct Counters {
    connections: AtomicU64,
    requests: AtomicU64,
    keepalive_reuses: AtomicU64,
    admitted: AtomicU64,
    streamed: AtomicU64,
    rejected_full: AtomicU64,
    rejected_draining: AtomicU64,
    unknown_lane: AtomicU64,
    malformed: AtomicU64,
    overloaded: AtomicU64,
    disconnects: AtomicU64,
    deadline_evictions: AtomicU64,
    drain_abandoned: AtomicU64,
    nonfinite: AtomicU64,
}

/// Owned snapshot of the transport counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CounterSnapshot {
    /// Accepted TCP connections.
    pub connections: u64,
    /// Parsed HTTP requests across all connections (≥ `connections`
    /// when keep-alive reuse happens).
    pub requests: u64,
    /// Requests beyond the first on their connection — the keep-alive
    /// reuse total.
    pub keepalive_reuses: u64,
    /// Requests admitted into a lane queue.
    pub admitted: u64,
    /// Completions delivered to a live client stream.
    pub streamed: u64,
    /// `429` responses (lane queue full).
    pub rejected_full: u64,
    /// `503` responses (lane closed / draining).
    pub rejected_draining: u64,
    /// `404` responses (no such lane).
    pub unknown_lane: u64,
    /// `400` responses (unparsable request).
    pub malformed: u64,
    /// Connections turned away at the `max_connections` cap (`503`).
    pub overloaded: u64,
    /// Streams whose client vanished before (or while) the result
    /// was written; the engine slot was freed and the completion
    /// accounted regardless.
    pub disconnects: u64,
    /// Connections evicted with `408` at the whole-request deadline
    /// (`request_deadline_ms`) or the inter-byte gap bound
    /// (`read_timeout_ms`).
    pub deadline_evictions: u64,
    /// Streams abandoned at the drain deadline (error chunk sent).
    pub drain_abandoned: u64,
    /// Responses containing a non-finite logit (overflow accounting,
    /// also available per lane in `/metrics`).
    pub nonfinite: u64,
}

/// Retained-sample bound for each lane's latency histogram: a
/// long-running server keeps memory `O(cap)` per lane via
/// [`LatencyHistogram::with_sample_cap`]'s deterministic
/// stride-doubling reservoir, while `_count`/`_sum`/`max` stay exact
/// running counters.
const LATENCY_SAMPLE_CAP: usize = 16_384;

/// Per-lane completion accounting on the transport side (what the
/// scheduler streamed to clients), feeding `/metrics` and the final
/// [`TransportReport`].
#[derive(Debug, Clone)]
struct StreamTally {
    completed: u64,
    deadline_misses: u64,
    nonfinite: u64,
    latency: LatencyHistogram,
}

impl Default for StreamTally {
    fn default() -> Self {
        StreamTally {
            completed: 0,
            deadline_misses: 0,
            nonfinite: 0,
            latency: LatencyHistogram::with_sample_cap(LATENCY_SAMPLE_CAP),
        }
    }
}

struct Shared {
    clock: Arc<WallClock>,
    /// Drain requested (SIGINT or handle): stop admitting inference.
    shutdown: AtomicBool,
    /// When the drain started (clock offset), once it has.
    drain_started: Mutex<Option<Duration>>,
    /// A worker died: pending streams error out instead of waiting.
    failed: AtomicBool,
    /// Completed batch entries awaiting the reactor (drained every
    /// wakeup; the workers never touch a socket).
    completions: Mutex<Vec<Outcome>>,
    /// The reactor's wake pipe, once [`Server::run`] created it.
    wake: Mutex<Option<Arc<WakePipe>>>,
    next_id: AtomicU64,
    /// Streams admitted but not yet answered or accounted.
    pending: AtomicUsize,
    /// Connections currently owned by the reactor.
    open_conns: AtomicUsize,
    counters: Counters,
    tallies: Mutex<Vec<StreamTally>>,
}

impl Shared {
    fn new() -> Shared {
        Shared {
            clock: Arc::new(WallClock::new()),
            shutdown: AtomicBool::new(false),
            drain_started: Mutex::new(None),
            failed: AtomicBool::new(false),
            completions: Mutex::new(Vec::new()),
            wake: Mutex::new(None),
            next_id: AtomicU64::new(1),
            pending: AtomicUsize::new(0),
            open_conns: AtomicUsize::new(0),
            counters: Counters::default(),
            tallies: Mutex::new(Vec::new()),
        }
    }

    fn counter_snapshot(&self) -> CounterSnapshot {
        let c = &self.counters;
        let ld = Ordering::Relaxed;
        CounterSnapshot {
            connections: c.connections.load(ld),
            requests: c.requests.load(ld),
            keepalive_reuses: c.keepalive_reuses.load(ld),
            admitted: c.admitted.load(ld),
            streamed: c.streamed.load(ld),
            rejected_full: c.rejected_full.load(ld),
            rejected_draining: c.rejected_draining.load(ld),
            unknown_lane: c.unknown_lane.load(ld),
            malformed: c.malformed.load(ld),
            overloaded: c.overloaded.load(ld),
            disconnects: c.disconnects.load(ld),
            deadline_evictions: c.deadline_evictions.load(ld),
            drain_abandoned: c.drain_abandoned.load(ld),
            nonfinite: c.nonfinite.load(ld),
        }
    }

    fn pending_streams(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    /// Cumulative `(completed, deadline_misses)` across all lanes —
    /// the drift monitor's miss-pressure feed.
    fn completion_counts(&self) -> (u64, u64) {
        let tallies = self.tallies.lock().unwrap();
        tallies.iter().fold((0, 0), |(done, missed), t| {
            (done + t.completed, missed + t.deadline_misses)
        })
    }

    fn is_draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || sigint_requested()
    }

    /// Tug the reactor's wake pipe, if the reactor is running.
    fn notify_waker(&self) {
        if let Some(wake) = &*self.wake.lock().unwrap() {
            wake.notify();
        }
    }

    /// The scheduler's streaming callback: account the completion per
    /// lane, queue the outcome for the reactor, and wake it.  Runs on
    /// the completing worker's thread, outside all scheduler locks —
    /// and never touches a socket.
    fn on_completion(&self, c: &Completion) {
        let finite = c.output.iter().all(|v| v.is_finite());
        {
            let mut tallies = self.tallies.lock().unwrap();
            let t = &mut tallies[c.lane];
            t.completed += 1;
            if c.missed_deadline {
                t.deadline_misses += 1;
            }
            if !finite {
                t.nonfinite += 1;
            }
            t.latency.record(c.latency);
        }
        if !finite {
            self.counters.nonfinite.fetch_add(1, Ordering::Relaxed);
        }
        self.completions.lock().unwrap().push(Outcome {
            id: c.request.id,
            lane: c.lane,
            latency: c.latency,
            missed_deadline: c.missed_deadline,
            finite,
            logits: c.output.to_vec(),
        });
        self.notify_waker();
    }
}

/// Cloneable control handle: request a drain, watch the live state.
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Request a graceful drain: stop admitting, flush the lanes,
    /// let [`Server::run`] return.  Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.notify_waker();
    }

    pub fn is_draining(&self) -> bool {
        self.shared.is_draining()
    }

    /// Streams admitted but not yet answered (or accounted) — zero
    /// after a clean drain.
    pub fn pending_streams(&self) -> usize {
        self.shared.pending_streams()
    }

    /// Connections currently owned by the reactor.
    pub fn open_connections(&self) -> usize {
        self.shared.open_conns.load(Ordering::SeqCst)
    }

    pub fn counters(&self) -> CounterSnapshot {
        self.shared.counter_snapshot()
    }
}

// ---------------------------------------------------------------------------
// Reports
// ---------------------------------------------------------------------------

/// One lane's transport-side slice of the run.
#[derive(Debug, Clone)]
pub struct LaneStreamReport {
    pub name: String,
    pub completed: u64,
    pub deadline_misses: u64,
    /// Completions containing a non-finite logit (overflow counter).
    pub nonfinite: u64,
    pub queue: QueueStats,
    pub latency: LatencyHistogram,
}

/// What [`Server::run`] returns after the drain finishes.
#[derive(Debug)]
pub struct TransportReport {
    pub wall: Duration,
    pub counters: CounterSnapshot,
    /// Streams left unaccounted after drain — zero unless something
    /// leaked (asserted in the integration tests).
    pub pending_streams: usize,
    /// Final pool counters — `busy == 0` after a clean drain.
    pub pool: PoolCounters,
    pub lanes: Vec<LaneStreamReport>,
    pub workers: Vec<WorkerReport>,
    /// Tracer snapshot at drain (empty when tracing was off) — what
    /// `GET /debug/trace` would have returned at the end.
    pub spans: Vec<Span>,
    /// Spans the tracer's ring dropped (oldest first).
    pub trace_dropped: u64,
}

impl TransportReport {
    pub fn print(&self) {
        let c = &self.counters;
        println!(
            "[serve/transport] {} connections, {} requests \
             ({} keep-alive reuses), {} admitted, {} streamed, \
             {} disconnects | rejected: {} full, {} draining, {} unknown \
             lane, {} malformed, {} overloaded, {} deadline-evicted | \
             wall {}",
            c.connections,
            c.requests,
            c.keepalive_reuses,
            c.admitted,
            c.streamed,
            c.disconnects,
            c.rejected_full,
            c.rejected_draining,
            c.unknown_lane,
            c.malformed,
            c.overloaded,
            c.deadline_evictions,
            human_duration(self.wall),
        );
        for lane in &self.lanes {
            let p99 = lane
                .latency
                .quantile(0.99)
                .map(human_duration)
                .unwrap_or_else(|| "-".into());
            println!(
                "        lane {}: {} completed, {} misses, {} non-finite, \
                 {} rejected, p99 {}",
                lane.name,
                lane.completed,
                lane.deadline_misses,
                lane.nonfinite,
                lane.queue.rejected,
                p99,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------------

/// A bound listener, ready to [`run`](Server::run).  Binding is
/// separate from running so callers learn the ephemeral port (tests
/// bind `127.0.0.1:0`) and can clone a [`ServerHandle`] before the
/// reactor takes the thread.
pub struct Server {
    listener: TcpListener,
    local: SocketAddr,
    tcfg: TransportConfig,
    trace: TraceConfig,
    autoscale: Option<AutoscalePolicy>,
    replan: Option<ReplanDriver>,
    service_models: Option<Vec<(u64, u64)>>,
    shared: Arc<Shared>,
}

impl Server {
    pub fn bind(tcfg: &TransportConfig) -> Result<Server> {
        tcfg.validate()?;
        let listener = TcpListener::bind(&tcfg.addr)
            .with_context(|| format!("bind {}", tcfg.addr))?;
        // Non-blocking accept: the reactor polls readiness instead of
        // parking in the kernel forever.
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        Ok(Server {
            listener,
            local,
            tcfg: tcfg.clone(),
            trace: TraceConfig::default(),
            autoscale: None,
            replan: None,
            service_models: None,
            shared: Arc::new(Shared::new()),
        })
    }

    /// Enable span tracing for the run (the `[trace]` table); spans
    /// become visible at `GET /debug/trace` and in the final
    /// [`TransportReport`].  Call before [`run`](Server::run).
    pub fn set_trace(&mut self, trace: TraceConfig) {
        self.trace = trace;
    }

    /// Drive the worker pool off the transport arrival path: start at
    /// `policy.min_workers` and let admissions grow the pool through
    /// [`Scheduler::poll_autoscale`].  Without this the pool is fixed
    /// at the `workers` count passed to [`run`](Server::run).
    pub fn set_autoscale(&mut self, policy: AutoscalePolicy) {
        self.autoscale = Some(policy);
    }

    /// Close the planner loop: the reactor feeds the driver's drift
    /// monitor from the live scheduler counters every window, and a
    /// sustained breach replans against the driver's (calibrated)
    /// service models and hot-swaps the lane bucket sets through
    /// [`Scheduler::adopt_plan`] — no drain, no dropped requests.
    /// Call before [`run`](Server::run).
    pub fn set_replan(&mut self, driver: ReplanDriver) {
        self.replan = Some(driver);
    }

    /// Seed the per-lane `(overhead_us, per_row_us)` service-model
    /// gauges `/metrics` exports (`mpx_serve_service_model`); live
    /// replans overwrite them.  Call before [`run`](Server::run).
    pub fn set_service_models(&mut self, models: Vec<(u64, u64)>) {
        self.service_models = Some(models);
    }

    /// The actually-bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: self.shared.clone() }
    }

    /// Serve until a drain is requested ([`ServerHandle::shutdown`]
    /// or SIGINT after [`install_sigint`]) and completes.  Blocks the
    /// calling thread: it becomes the reactor; worker threads (the
    /// fixed `workers` count, or the autoscale policy's range when
    /// [`set_autoscale`](Server::set_autoscale) was called) are
    /// spawned inside.  `make_executor(worker, lane)` runs on the
    /// worker's own thread (PJRT literals are thread-local);
    /// `image_elems` is the flattened input row length every lane
    /// accepts (payloads of any other size are `400`-rejected before
    /// they can reach an executor).
    pub fn run<E, F>(
        self,
        lanes: Vec<LaneSpec>,
        workers: usize,
        policy: SchedPolicy,
        image_elems: usize,
        make_executor: F,
    ) -> Result<TransportReport>
    where
        E: BatchExecutor,
        F: Fn(usize, usize) -> Result<E> + Sync,
    {
        let shared = self.shared;
        let tcfg = self.tcfg;
        let nlanes = lanes.len();
        anyhow::ensure!(nlanes > 0, "transport: no lanes");
        anyhow::ensure!(workers > 0, "transport: no workers");
        *shared.tallies.lock().unwrap() =
            vec![StreamTally::default(); nlanes];

        // Best-effort: the connection budget should not be capped by
        // the usual 1024-descriptor soft default.
        let _ = reactor::raise_nofile_limit(
            tcfg.max_connections as u64 * 2 + 64,
        );

        // Routing table: full lane names always route.  The suffix
        // after the last '/' ("chat" for "vit_tiny/chat") routes too,
        // but only when it is unambiguous — shared by no other lane's
        // suffix and not itself some lane's full name (a full-name
        // route is never shadowed or removed by suffix handling).
        let mut routes: HashMap<String, usize> = HashMap::new();
        for (i, spec) in lanes.iter().enumerate() {
            routes.insert(spec.name.clone(), i);
        }
        for (i, spec) in lanes.iter().enumerate() {
            let Some(suffix) = lane_suffix(&spec.name) else {
                continue;
            };
            let shared_suffix = lanes.iter().enumerate().any(|(j, other)| {
                j != i && lane_suffix(&other.name) == Some(suffix)
            });
            if !shared_suffix && !routes.contains_key(suffix) {
                routes.insert(suffix.to_string(), i);
            }
        }
        let lane_names: Vec<String> =
            lanes.iter().map(|s| s.name.clone()).collect();
        let deadlines: Vec<Duration> =
            lanes.iter().map(|s| s.deadline).collect();

        let autoscale = self
            .autoscale
            .unwrap_or_else(|| AutoscalePolicy::fixed(workers));
        let n0 = autoscale.min_workers.max(1);

        let cb_shared = shared.clone();
        let on_complete: Box<CompletionFn> =
            Box::new(move |c: &Completion| cb_shared.on_completion(c));
        let clock: Arc<dyn Clock> = shared.clock.clone();
        let tracer = Tracer::from_config(clock.clone(), &self.trace);
        let mut sched = Scheduler::new(
            lanes,
            policy,
            autoscale,
            clock,
            Some(on_complete),
        )?;
        if let Some(t) = &tracer {
            sched.set_tracer(t.clone());
        }
        let sched = Arc::new(sched);
        if let Some(models) = &self.service_models {
            sched.set_lane_models(models);
        }
        let mut replan = self.replan;

        let wake = Arc::new(
            WakePipe::new().context("transport wake pipe")?,
        );
        // The Arc in `shared` keeps the pipe's descriptors open for
        // as long as any ServerHandle lives, so a post-run
        // `shutdown()` notifies a still-valid (just unread) pipe
        // instead of whatever descriptor number got recycled.
        *shared.wake.lock().unwrap() = Some(wake.clone());

        let t_start = shared.clock.now();
        let ready = std::sync::Barrier::new(n0 + 1);
        let listener = self.listener;

        let (worker_reports, fatal) = std::thread::scope(|scope| {
            let sched: &Scheduler = &sched;
            let shared: &Shared = &shared;
            let make_executor = &make_executor;
            let ready = &ready;
            let tcfg = &tcfg;
            let routes = &routes;
            let lane_names = &lane_names;
            let deadlines = &deadlines;
            let replan = &mut replan;

            // Spawned at startup (with_barrier) and again from the
            // arrival path when the autoscale policy asks for more.
            let spawn_worker = |w: usize, with_barrier: bool| {
                scope.spawn(move || {
                    let execs: Result<Vec<E>> = (0..nlanes)
                        .map(|lane| make_executor(w, lane))
                        .collect();
                    // Pass the barrier success or not, or run would
                    // wedge below.
                    if with_barrier {
                        ready.wait();
                    }
                    let out = match execs {
                        Ok(mut execs) => worker_loop(
                            w,
                            &mut execs,
                            sched,
                            &*shared.clock,
                        ),
                        Err(e) => {
                            sched.worker_aborted();
                            Err(e)
                        }
                    };
                    if out.is_err() {
                        // A dead worker drains the server: stop
                        // admitting, error the pending streams.
                        shared.failed.store(true, Ordering::SeqCst);
                        shared.shutdown.store(true, Ordering::SeqCst);
                        sched.close_all();
                        shared.notify_waker();
                    }
                    out
                })
            };

            sched.register_workers(n0);
            let mut handles: Vec<_> =
                (0..n0).map(|w| spawn_worker(w, true)).collect();
            let mut next_worker = n0;
            ready.wait();

            // ----- reactor loop (this thread) -----
            let ctx = ReactorCtx {
                shared,
                sched,
                tcfg,
                routes,
                lane_names,
                deadlines,
                image_elems,
            };
            let mut r = Reactor::new(ctx, &listener);
            let mut drain_closed = false;
            let mut failed_abandoned = false;
            let mut fatal: Option<io::Error> = None;
            loop {
                if shared.is_draining() {
                    shared.shutdown.store(true, Ordering::SeqCst);
                    if !drain_closed {
                        *shared.drain_started.lock().unwrap() =
                            Some(shared.clock.now());
                        sched.close_all();
                        drain_closed = true;
                    }
                }
                if shared.failed.load(Ordering::SeqCst) && !failed_abandoned
                {
                    r.abandon_streams("worker failed");
                    failed_abandoned = true;
                }
                if drain_closed {
                    let started =
                        shared.drain_started.lock().unwrap().unwrap();
                    if shared.clock.now()
                        > started + tcfg.drain_deadline()
                    {
                        r.abandon_streams("drain deadline exceeded");
                    }
                    // Keep serving during the drain (new inference
                    // gets an orderly 503; /healthz and /metrics keep
                    // answering) until the pending streams flush.
                    if shared.pending_streams() == 0 {
                        r.flush_all();
                        break;
                    }
                }

                r.build_poll_set(wake.read_fd());
                if let Err(e) = poll_ready(&mut r.fds, TICK_MS) {
                    fatal = Some(e);
                    shared.shutdown.store(true, Ordering::SeqCst);
                    sched.close_all();
                    break;
                }
                if r.fds[1].readable() {
                    wake.drain();
                }

                // Completions first: routing a result frees pipeline
                // slots before new reads are serviced.
                let outcomes = std::mem::take(
                    &mut *shared.completions.lock().unwrap(),
                );
                if !outcomes.is_empty() {
                    r.route_outcomes(outcomes);
                }
                if r.fds[0].readable() {
                    r.accept_all();
                }
                r.service_ready();

                // Autoscale rides the arrival path: any admission
                // this tick may grow the pool.
                if r.take_admitted() && !drain_closed {
                    if let ScaleOp::Spawn(k) = sched.poll_autoscale() {
                        sched.register_workers(k);
                        for _ in 0..k {
                            handles.push(spawn_worker(next_worker, false));
                            next_worker += 1;
                        }
                    }
                }

                // Drift watch: once per window, feed the replan
                // driver the cumulative scheduler/stream counters; a
                // sustained breach hot-swaps the lane plans in place.
                // An adopt error is a bug in the produced plan, not
                // in the traffic — log it and keep serving the old
                // plan rather than dropping the reactor.
                if !drain_closed {
                    if let Some(d) = replan.as_mut() {
                        let now = shared.clock.now();
                        if d.due(now) {
                            let accepted: Vec<u64> = (0..nlanes)
                                .map(|i| sched.lane_stats(i).accepted)
                                .collect();
                            let (done, missed) =
                                shared.completion_counts();
                            match d.poll(now, &accepted, done, missed) {
                                Ok(Some(rt)) => {
                                    match sched
                                        .adopt_plan(&rt.updates, rt.full)
                                    {
                                        Ok(out) => eprintln!(
                                            "[mpx] serve: replan #{}: {} \
                                             lane(s) retuned — {}{}",
                                            out.ordinal,
                                            out.lanes_changed,
                                            rt.reason,
                                            if rt.full {
                                                ""
                                            } else {
                                                " (partial: constrained \
                                                 to compiled buckets)"
                                            },
                                        ),
                                        Err(e) => eprintln!(
                                            "[mpx] serve: replan adopt \
                                             failed: {e}"
                                        ),
                                    }
                                }
                                Ok(None) => {}
                                Err(e) => eprintln!(
                                    "[mpx] serve: replan failed: {e}"
                                ),
                            }
                        }
                    }
                }

                r.sweep();
                r.reap();
            }

            let reports = handles
                .into_iter()
                .map(|h| h.join().expect("transport worker panicked"))
                .collect::<Result<Vec<_>>>();
            (reports, fatal)
        });
        if let Some(e) = fatal {
            return Err(anyhow::Error::new(e).context("transport poll loop"));
        }
        let worker_reports = worker_reports?;

        let wall = shared.clock.now().saturating_sub(t_start);
        let tallies = std::mem::take(&mut *shared.tallies.lock().unwrap());
        let lanes = tallies
            .into_iter()
            .enumerate()
            .map(|(i, t)| LaneStreamReport {
                name: lane_names[i].clone(),
                completed: t.completed,
                deadline_misses: t.deadline_misses,
                nonfinite: t.nonfinite,
                queue: sched.lane_stats(i),
                latency: t.latency,
            })
            .collect();
        let (spans, trace_dropped) = match &tracer {
            Some(t) => (t.snapshot(), t.dropped()),
            None => (Vec::new(), 0),
        };
        Ok(TransportReport {
            wall,
            counters: shared.counter_snapshot(),
            pending_streams: shared.pending_streams(),
            pool: sched.counters(),
            lanes,
            workers: worker_reports,
            spans,
            trace_dropped,
        })
    }
}

/// The short routing alias of a lane name: the part after the last
/// `/` ("chat" for "vit_tiny/chat"); `None` when there is no slash.
fn lane_suffix(name: &str) -> Option<&str> {
    let s = name.rsplit('/').next().unwrap_or("");
    (!s.is_empty() && s != name).then_some(s)
}

// ---------------------------------------------------------------------------
// The reactor: per-connection state machines on one poll loop
// ---------------------------------------------------------------------------

/// Poll timeout: the sweep cadence for deadlines and drain checks.
/// Every latency-relevant event (accept, readable socket, completed
/// batch via the wake pipe) interrupts the wait immediately.
const TICK_MS: i32 = 25;

/// Per-`read(2)` scratch size.
const READ_BUF: usize = 16 * 1024;

/// A routed result waiting to be spliced into its connection's
/// output, in request order.
struct StreamResult {
    /// Serialized chunk(s): the result (or error) line plus the
    /// chunked-encoding terminator.
    bytes: Vec<u8>,
    /// When the completion reached the reactor (egress span start).
    arrived: Duration,
    /// Drain/failure abandonment (error chunk) rather than a result.
    abandoned: bool,
}

/// One queued response on a connection.  Responses leave in exactly
/// the order requests arrived — HTTP/1.1 pipelining.
enum PendingBody {
    /// A fully serialized response (everything except infer).
    Ready(Vec<u8>),
    /// An admitted inference stream: headers + ack chunk go out
    /// immediately (once at the queue front), the result chunk when
    /// the engine completes it.
    Stream {
        id: u64,
        lane: usize,
        head: Vec<u8>,
        head_sent: bool,
        result: Option<StreamResult>,
    },
}

struct Pending {
    keep_alive: bool,
    body: PendingBody,
}

/// One connection's state machine.
struct Conn {
    stream: TcpStream,
    parser: http::RequestParser,
    /// Bytes ready for the socket; `out_pos` marks how far the
    /// kernel has taken them.
    out: Vec<u8>,
    out_pos: usize,
    /// Responses in request order (pipelining queue).
    pending: VecDeque<Pending>,
    /// First byte of the currently-parsing request (whole-request
    /// deadline anchor); `None` at a message boundary.
    req_start: Option<Duration>,
    /// Last byte read (inter-byte `read_timeout_ms` anchor).
    last_byte: Duration,
    /// Last read or successful write (idle-timeout anchor).
    last_activity: Duration,
    /// Requests parsed on this connection (keep-alive reuse count).
    requests: u64,
    /// Accept ordinal (the `conn` attr on accept/read_deadline
    /// trace instants).
    ordinal: u64,
    /// Stop reading; close once the pending queue and `out` flush.
    close_after: bool,
    /// Orderly FIN seen: never read or write again, but wait for
    /// in-flight streams so their completions are accounted as
    /// disconnects.
    peer_gone: bool,
    /// Hard failure or fully closed: reap at the end of the tick.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, ordinal: u64, now: Duration) -> Conn {
        Conn {
            stream,
            parser: http::RequestParser::new(),
            out: Vec::new(),
            out_pos: 0,
            pending: VecDeque::new(),
            req_start: None,
            last_byte: now,
            last_activity: now,
            requests: 0,
            ordinal,
            close_after: false,
            peer_gone: false,
            dead: false,
        }
    }

    fn flushed(&self) -> bool {
        self.out_pos >= self.out.len()
    }
}

/// Everything the per-connection handlers need, bundled so free
/// functions and methods share one `Copy` parameter.
#[derive(Clone, Copy)]
struct ReactorCtx<'a> {
    shared: &'a Shared,
    sched: &'a Scheduler,
    tcfg: &'a TransportConfig,
    routes: &'a HashMap<String, usize>,
    lane_names: &'a [String],
    deadlines: &'a [Duration],
    image_elems: usize,
}

impl ReactorCtx<'_> {
    /// 429 Retry-After: one flush window is how long the dispatch
    /// policy takes to clear a sub-bucket backlog, so it is the
    /// honest "when is a slot likely free" hint.  Read live from the
    /// scheduler (not a startup snapshot) — a replan that retunes a
    /// lane's flush timeout retunes its hint too.
    fn retry_after_s(&self, lane: usize) -> u64 {
        let flush = self.sched.lane_flush_timeouts()[lane];
        (flush.as_secs_f64().ceil() as u64).max(1)
    }
}

struct Reactor<'a> {
    ctx: ReactorCtx<'a>,
    listener: &'a TcpListener,
    /// Connection slab; `free` recycles vacated slots.
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    /// request id → slab index of the connection streaming it.
    id_map: HashMap<u64, usize>,
    live: usize,
    /// An admission happened since the last autoscale poll.
    admitted: bool,
    /// Rebuilt every tick: `[listener, wake, conns...]`.
    fds: Vec<PollFd>,
    /// `fds[i + 2]` belongs to `conns[fd_conn[i]]`.
    fd_conn: Vec<usize>,
}

impl<'a> Reactor<'a> {
    fn new(ctx: ReactorCtx<'a>, listener: &'a TcpListener) -> Reactor<'a> {
        Reactor {
            ctx,
            listener,
            conns: Vec::new(),
            free: Vec::new(),
            id_map: HashMap::new(),
            live: 0,
            admitted: false,
            fds: Vec::new(),
            fd_conn: Vec::new(),
        }
    }

    fn take_admitted(&mut self) -> bool {
        std::mem::take(&mut self.admitted)
    }

    /// Rebuild the poll set.  A connection is read-polled unless it
    /// is closing or its pipeline is full, and write-polled while
    /// `out` has unflushed bytes; one with neither (parked on the
    /// engine) is left out entirely — the wake pipe covers it.
    fn build_poll_set(&mut self, wake_fd: c_int) {
        self.fds.clear();
        self.fd_conn.clear();
        self.fds.push(PollFd::new(self.listener.as_raw_fd(), POLLIN));
        self.fds.push(PollFd::new(wake_fd, POLLIN));
        for (idx, conn) in self.conns.iter().enumerate() {
            let Some(conn) = conn else { continue };
            if conn.dead || conn.peer_gone {
                continue;
            }
            let mut events: c_short = 0;
            if !conn.close_after
                && conn.pending.len() < self.ctx.tcfg.max_pipelined
            {
                events |= POLLIN;
            }
            if !conn.flushed() {
                events |= POLLOUT;
            }
            if events != 0 {
                self.fds
                    .push(PollFd::new(conn.stream.as_raw_fd(), events));
                self.fd_conn.push(idx);
            }
        }
    }

    /// Accept everything the backlog holds; no sleeps — an empty
    /// backlog is just `WouldBlock` and the next tick's poll.
    fn accept_all(&mut self) {
        let ctx = self.ctx;
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let ordinal = ctx
                        .shared
                        .counters
                        .connections
                        .fetch_add(1, Ordering::Relaxed)
                        + 1;
                    let now = ctx.shared.clock.now();
                    if let Some(t) = ctx.sched.tracer() {
                        t.instant(SpanKind::Accept, now, ordinal, 0, 0);
                    }
                    if self.live >= ctx.tcfg.max_connections {
                        ctx.shared
                            .counters
                            .overloaded
                            .fetch_add(1, Ordering::Relaxed);
                        turn_away(stream);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let conn = Conn::new(stream, ordinal, now);
                    match self.free.pop() {
                        Some(idx) => self.conns[idx] = Some(conn),
                        None => self.conns.push(Some(conn)),
                    }
                    self.live += 1;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                // Transient accept failure (EMFILE, reset): retry on
                // the next tick rather than spinning.
                Err(_) => break,
            }
        }
        ctx.shared.open_conns.store(self.live, Ordering::SeqCst);
    }

    /// Run one connection through read → parse → respond → flush.
    /// `readable` is the poll verdict; completions and write-ready
    /// wakeups pass `false` and only parse/pump.
    fn service_conn(&mut self, idx: usize, readable: bool) {
        let Some(mut conn) = self.conns[idx].take() else {
            return;
        };
        let ctx = self.ctx;
        if readable && !conn.dead && !conn.peer_gone {
            read_into(ctx, &mut conn);
        }
        loop {
            if conn.dead {
                break;
            }
            let parsed = self.drain_parser(&mut conn, idx);
            pump(ctx, &mut conn);
            if parsed == 0 {
                // Nothing new materialized; buffered bytes beyond
                // the pipeline cap wait for a completion to free a
                // slot (route_outcomes re-enters here).
                break;
            }
        }
        self.conns[idx] = Some(conn);
    }

    /// Poll verdicts → connections (collected first: servicing can
    /// mutate the slab).
    fn service_ready(&mut self) {
        let ready: Vec<(usize, bool)> = self
            .fds
            .iter()
            .skip(2)
            .zip(self.fd_conn.iter())
            .filter(|(fd, _)| fd.revents != 0)
            .map(|(fd, &idx)| (idx, fd.readable()))
            .collect();
        for (idx, readable) in ready {
            self.service_conn(idx, readable);
        }
    }

    /// Extract complete requests up to the pipeline cap and queue
    /// their responses.  Returns how many requests were handled.
    fn drain_parser(&mut self, conn: &mut Conn, idx: usize) -> usize {
        let ctx = self.ctx;
        let mut handled = 0;
        loop {
            if conn.dead
                || conn.close_after
                || conn.peer_gone
                || conn.pending.len() >= ctx.tcfg.max_pipelined
            {
                break;
            }
            match conn.parser.next_request() {
                Ok(Some(req)) => {
                    handled += 1;
                    self.handle_request(conn, idx, req);
                }
                Ok(None) => break,
                Err(e) => {
                    // Framing errors are terminal: the byte stream
                    // cannot be resynchronized.
                    ctx.shared
                        .counters
                        .malformed
                        .fetch_add(1, Ordering::Relaxed);
                    push_ready(
                        conn,
                        false,
                        error_bytes(
                            400,
                            "Bad Request",
                            false,
                            &e.to_string(),
                        ),
                    );
                    break;
                }
            }
        }
        // 100-continue interim bytes are only safe between
        // responses — inside a chunked response they would corrupt
        // the framing; RFC 7231 permits dropping them.
        if let Some(interim) = conn.parser.take_interim() {
            if conn.pending.is_empty() && !conn.peer_gone {
                conn.out.extend_from_slice(&interim);
            }
        }
        // Whole-request deadline anchor maintenance.
        if conn.parser.mid_request() {
            if conn.req_start.is_none() {
                conn.req_start = Some(ctx.shared.clock.now());
            }
        } else {
            conn.req_start = None;
        }
        handled
    }

    /// Route one parsed request to its endpoint.
    fn handle_request(
        &mut self,
        conn: &mut Conn,
        idx: usize,
        req: http::HttpRequest,
    ) {
        let ctx = self.ctx;
        conn.requests += 1;
        ctx.shared.counters.requests.fetch_add(1, Ordering::Relaxed);
        if conn.requests > 1 {
            ctx.shared
                .counters
                .keepalive_reuses
                .fetch_add(1, Ordering::Relaxed);
        }
        let ka = req.wants_keep_alive();
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => {
                let body =
                    healthz_json(ctx.shared, ctx.sched, ctx.lane_names);
                push_ready(
                    conn,
                    ka,
                    response_bytes(
                        200,
                        "OK",
                        "application/json",
                        ka,
                        &[],
                        body.as_bytes(),
                    ),
                );
            }
            ("GET", "/metrics") => {
                let body = prometheus_text(
                    ctx.shared,
                    ctx.sched,
                    ctx.lane_names,
                );
                push_ready(
                    conn,
                    ka,
                    response_bytes(
                        200,
                        "OK",
                        "text/plain; version=0.0.4",
                        ka,
                        &[],
                        body.as_bytes(),
                    ),
                );
            }
            ("GET", "/debug/trace") => match ctx.sched.tracer() {
                Some(t) => {
                    // The ring's whole content (the last
                    // `buffer_spans` recorded), as a Chrome trace
                    // document — save the body to a file and load it
                    // in Perfetto as-is.
                    let doc =
                        chrome::chrome_trace(&t.snapshot(), t.dropped());
                    push_ready(
                        conn,
                        ka,
                        response_bytes(
                            200,
                            "OK",
                            "application/json",
                            ka,
                            &[],
                            (doc.dump() + "\n").as_bytes(),
                        ),
                    );
                }
                None => push_ready(
                    conn,
                    ka,
                    error_bytes(
                        404,
                        "Not Found",
                        ka,
                        "tracing is disabled ([trace] enabled = false)",
                    ),
                ),
            },
            ("POST", "/v1/infer") => {
                self.handle_infer(conn, idx, &req, ka);
            }
            _ => push_ready(
                conn,
                ka,
                error_bytes(
                    404,
                    "Not Found",
                    ka,
                    &format!("no endpoint {} {}", req.method, req.path),
                ),
            ),
        }
    }

    /// Parse, admit, and enqueue one inference request.
    fn handle_infer(
        &mut self,
        conn: &mut Conn,
        idx: usize,
        req: &http::HttpRequest,
        ka: bool,
    ) {
        let ctx = self.ctx;
        let (lane, image) =
            match parse_infer(req, ctx.routes, ctx.image_elems) {
                Ok(ok) => ok,
                Err(InferReject::Malformed(msg)) => {
                    ctx.shared
                        .counters
                        .malformed
                        .fetch_add(1, Ordering::Relaxed);
                    push_ready(
                        conn,
                        ka,
                        error_bytes(400, "Bad Request", ka, &msg),
                    );
                    return;
                }
                Err(InferReject::UnknownLane(name)) => {
                    ctx.shared
                        .counters
                        .unknown_lane
                        .fetch_add(1, Ordering::Relaxed);
                    push_ready(
                        conn,
                        ka,
                        error_bytes(
                            404,
                            "Not Found",
                            ka,
                            &format!(
                                "unknown lane {name:?} (serving: {})",
                                ctx.lane_names.join(", ")
                            ),
                        ),
                    );
                    return;
                }
            };

        // Draining: an orderly 503 before touching the queue.
        if ctx.shared.is_draining() {
            ctx.shared
                .counters
                .rejected_draining
                .fetch_add(1, Ordering::Relaxed);
            push_ready(conn, ka, draining_bytes(ctx.tcfg, ka));
            return;
        }

        let id = ctx.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let request = Request::new(
            id,
            image,
            ctx.deadlines[lane],
            ctx.shared.clock.now(),
        );
        if !ctx.sched.submit(lane, request) {
            if ctx.sched.lane_is_closed(lane) {
                ctx.shared
                    .counters
                    .rejected_draining
                    .fetch_add(1, Ordering::Relaxed);
                push_ready(conn, ka, draining_bytes(ctx.tcfg, ka));
            } else {
                ctx.shared
                    .counters
                    .rejected_full
                    .fetch_add(1, Ordering::Relaxed);
                let msg = format!(
                    "lane {} queue is full",
                    ctx.lane_names[lane]
                );
                let retry_after = ctx.retry_after_s(lane);
                let body = format!(
                    "{{\"error\":{},\"retry_after_s\":{}}}\n",
                    jstr(&msg),
                    retry_after
                );
                push_ready(
                    conn,
                    ka,
                    response_bytes(
                        429,
                        "Too Many Requests",
                        "application/json",
                        ka,
                        &[("Retry-After", retry_after.to_string())],
                        body.as_bytes(),
                    ),
                );
            }
            return;
        }
        ctx.shared.counters.admitted.fetch_add(1, Ordering::Relaxed);
        ctx.shared.pending.fetch_add(1, Ordering::SeqCst);
        self.admitted = true;

        // Admitted: headers + ack chunk as soon as this response
        // reaches the queue front; result chunk on completion.
        let ack = format!(
            "{{\"status\":\"queued\",\"id\":{id},\"lane\":{}}}\n",
            jstr(&ctx.lane_names[lane])
        );
        let mut head = Vec::with_capacity(256);
        let _ = http::start_chunked(
            &mut head,
            200,
            "OK",
            "application/x-ndjson",
            ka,
            &[],
        );
        let _ = http::write_chunk(&mut head, ack.as_bytes());
        conn.pending.push_back(Pending {
            keep_alive: ka,
            body: PendingBody::Stream {
                id,
                lane,
                head,
                head_sent: false,
                result: None,
            },
        });
        if !ka {
            conn.close_after = true;
        }
        self.id_map.insert(id, idx);
    }

    /// Splice completed outcomes into their connections' response
    /// queues, then pump every touched connection.
    fn route_outcomes(&mut self, outcomes: Vec<Outcome>) {
        let arrived = self.ctx.shared.clock.now();
        let mut touched: Vec<usize> = Vec::new();
        for out in outcomes {
            // Late completions (stream already abandoned or its
            // client already accounted as a disconnect) route
            // nowhere; the engine-side tallies took them in
            // on_completion.
            let Some(idx) = self.id_map.remove(&out.id) else {
                continue;
            };
            let Some(conn) = self.conns[idx].as_mut() else {
                continue;
            };
            let line = outcome_json(&out, &self.ctx.lane_names[out.lane]);
            for p in conn.pending.iter_mut() {
                if let PendingBody::Stream { id, result, .. } = &mut p.body
                {
                    if *id == out.id && result.is_none() {
                        let mut bytes =
                            Vec::with_capacity(line.len() + 32);
                        let _ = http::write_chunk(
                            &mut bytes,
                            line.as_bytes(),
                        );
                        let _ = http::finish_chunked(&mut bytes);
                        *result = Some(StreamResult {
                            bytes,
                            arrived,
                            abandoned: false,
                        });
                        break;
                    }
                }
            }
            touched.push(idx);
        }
        touched.sort_unstable();
        touched.dedup();
        for idx in touched {
            self.service_conn(idx, false);
        }
    }

    /// Resolve every still-waiting stream with an error chunk (drain
    /// deadline passed, or a worker died).  Idempotent.
    fn abandon_streams(&mut self, reason: &str) {
        let arrived = self.ctx.shared.clock.now();
        for conn in self.conns.iter_mut().flatten() {
            for p in conn.pending.iter_mut() {
                let PendingBody::Stream { id, result, .. } = &mut p.body
                else {
                    continue;
                };
                if result.is_some() {
                    continue;
                }
                self.id_map.remove(id);
                let line = format!(
                    "{{\"id\":{id},\"error\":{}}}\n",
                    jstr(reason)
                );
                let mut bytes = Vec::with_capacity(line.len() + 32);
                let _ = http::write_chunk(&mut bytes, line.as_bytes());
                let _ = http::finish_chunked(&mut bytes);
                *result = Some(StreamResult {
                    bytes,
                    arrived,
                    abandoned: true,
                });
            }
        }
        self.flush_all();
    }

    /// Best-effort pump of every connection (nonblocking writes).
    fn flush_all(&mut self) {
        let ctx = self.ctx;
        for conn in self.conns.iter_mut().flatten() {
            pump(ctx, conn);
        }
    }

    /// Deadline enforcement, once per tick: evict trickling clients
    /// mid-request (408 + close), silently close idle keep-alive
    /// connections.
    fn sweep(&mut self) {
        let ctx = self.ctx;
        let now = ctx.shared.clock.now();
        for conn in self.conns.iter_mut().flatten() {
            if conn.dead || conn.peer_gone || conn.close_after {
                continue;
            }
            if conn.parser.mid_request() {
                // Only while the *client* is the slow side: a full
                // pipeline (requests buffered behind the cap) is our
                // backpressure, not their trickle.
                if conn.pending.len() >= ctx.tcfg.max_pipelined {
                    continue;
                }
                let anchor = conn.req_start.unwrap_or(conn.last_byte);
                let overdue = now
                    > anchor + ctx.tcfg.request_deadline()
                    || now > conn.last_byte + ctx.tcfg.read_timeout();
                if overdue {
                    ctx.shared
                        .counters
                        .deadline_evictions
                        .fetch_add(1, Ordering::Relaxed);
                    if let Some(t) = ctx.sched.tracer() {
                        t.instant(
                            SpanKind::ReadDeadline,
                            now,
                            conn.ordinal,
                            0,
                            0,
                        );
                    }
                    push_ready(
                        conn,
                        false,
                        response_bytes(
                            408,
                            "Request Timeout",
                            "application/json",
                            false,
                            &[],
                            b"{\"error\":\"request deadline exceeded\"}\n",
                        ),
                    );
                    pump(ctx, conn);
                }
            } else if conn.pending.is_empty()
                && conn.flushed()
                && now > conn.last_activity + ctx.tcfg.idle_timeout()
            {
                // Idle keep-alive connection past its budget: silent
                // close, no counter — this is normal lifecycle.
                conn.dead = true;
            }
        }
    }

    /// Remove finished connections and account anything they still
    /// owed: un-routed streams on a dead connection are disconnects
    /// (or drain-abandoned, when the error chunk never flushed).
    fn reap(&mut self) {
        let ctx = self.ctx;
        for idx in 0..self.conns.len() {
            let done = match &self.conns[idx] {
                Some(conn) => {
                    conn.dead
                        || (conn.close_after
                            && conn.pending.is_empty()
                            && conn.flushed())
                }
                None => false,
            };
            if !done {
                continue;
            }
            let conn = self.conns[idx].take().unwrap();
            for p in &conn.pending {
                let PendingBody::Stream { id, result, .. } = &p.body
                else {
                    continue;
                };
                self.id_map.remove(id);
                match result {
                    Some(res) if res.abandoned => ctx
                        .shared
                        .counters
                        .drain_abandoned
                        .fetch_add(1, Ordering::Relaxed),
                    _ => ctx
                        .shared
                        .counters
                        .disconnects
                        .fetch_add(1, Ordering::Relaxed),
                };
                ctx.shared.pending.fetch_sub(1, Ordering::SeqCst);
            }
            self.free.push(idx);
            self.live -= 1;
        }
        ctx.shared.open_conns.store(self.live, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Per-connection I/O (free functions: shared by reactor methods)
// ---------------------------------------------------------------------------

/// Materialize queued responses into `out` (in request order) and
/// flush as much as the socket takes.  This is where a delivered
/// stream is accounted (`streamed`/`drain_abandoned`, the egress
/// span, and the pending-stream decrement).
fn pump(ctx: ReactorCtx<'_>, conn: &mut Conn) {
    if conn.dead {
        return;
    }
    if conn.peer_gone {
        pump_peer_gone(ctx, conn);
        return;
    }
    loop {
        let Some(p) = conn.pending.front_mut() else { break };
        let done = match &mut p.body {
            PendingBody::Ready(bytes) => {
                conn.out.append(bytes);
                true
            }
            PendingBody::Stream { id, lane, head, head_sent, result } => {
                if !*head_sent {
                    conn.out.append(head);
                    *head_sent = true;
                }
                match result.take() {
                    Some(res) => {
                        conn.out.extend_from_slice(&res.bytes);
                        if res.abandoned {
                            ctx.shared
                                .counters
                                .drain_abandoned
                                .fetch_add(1, Ordering::Relaxed);
                        } else {
                            ctx.shared
                                .counters
                                .streamed
                                .fetch_add(1, Ordering::Relaxed);
                            if let Some(t) = ctx.sched.tracer() {
                                // Completion-arrival → handoff of the
                                // serialized result chunk to the
                                // socket — the only transport-side
                                // latency a client sees beyond the
                                // engine's service span.
                                t.record(
                                    SpanKind::Egress,
                                    res.arrived,
                                    ctx.shared.clock.now(),
                                    *lane as u64,
                                    *id,
                                    0,
                                );
                            }
                        }
                        ctx.shared.pending.fetch_sub(1, Ordering::SeqCst);
                        true
                    }
                    None => false,
                }
            }
        };
        if !done {
            break;
        }
        let p = conn.pending.pop_front().unwrap();
        if !p.keep_alive {
            conn.close_after = true;
        }
    }
    while !conn.flushed() {
        match conn.stream.write(&conn.out[conn.out_pos..]) {
            Ok(0) => {
                conn.dead = true;
                return;
            }
            Ok(n) => {
                conn.out_pos += n;
                conn.last_activity = ctx.shared.clock.now();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                // Hard write failure (reset): the peer is gone for
                // real; reap accounts any unresolved streams as
                // disconnects.
                conn.dead = true;
                return;
            }
        }
    }
    if conn.flushed() && !conn.out.is_empty() {
        conn.out.clear();
        conn.out_pos = 0;
    }
}

/// The peer FIN'd: drop every response unwritten, but hold the
/// connection until its in-flight streams resolve so each completion
/// is accounted (disconnect, or drain-abandoned) exactly once.
fn pump_peer_gone(ctx: ReactorCtx<'_>, conn: &mut Conn) {
    while let Some(p) = conn.pending.front_mut() {
        match &mut p.body {
            PendingBody::Ready(_) => {
                conn.pending.pop_front();
            }
            PendingBody::Stream { result, .. } => match result.take() {
                Some(res) => {
                    if res.abandoned {
                        ctx.shared
                            .counters
                            .drain_abandoned
                            .fetch_add(1, Ordering::Relaxed);
                    } else {
                        ctx.shared
                            .counters
                            .disconnects
                            .fetch_add(1, Ordering::Relaxed);
                    }
                    ctx.shared.pending.fetch_sub(1, Ordering::SeqCst);
                    conn.pending.pop_front();
                }
                None => break,
            },
        }
    }
    conn.out.clear();
    conn.out_pos = 0;
    if conn.pending.is_empty() {
        conn.dead = true;
    }
}

/// Read whatever the socket holds into the parser, bounded by the
/// pipeline cap (backpressure: a capped connection is not re-polled
/// for reads, so the kernel buffer — and then TCP flow control —
/// absorbs the rest).
fn read_into(ctx: ReactorCtx<'_>, conn: &mut Conn) {
    let mut buf = [0u8; READ_BUF];
    loop {
        if conn.close_after
            || conn.peer_gone
            || conn.dead
            || conn.pending.len() >= ctx.tcfg.max_pipelined
        {
            break;
        }
        match conn.stream.read(&mut buf) {
            Ok(0) => {
                conn.peer_gone = true;
                break;
            }
            Ok(n) => {
                let now = ctx.shared.clock.now();
                conn.last_byte = now;
                conn.last_activity = now;
                conn.parser.feed(&buf[..n]);
                if n < buf.len() {
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
}

/// Over the connection cap: answer 503 with a single best-effort
/// nonblocking write (the ~150-byte response fits any socket buffer)
/// and drop the socket.
fn turn_away(stream: TcpStream) {
    let mut stream = stream;
    let _ = stream.set_nonblocking(true);
    let body = response_bytes(
        503,
        "Service Unavailable",
        "application/json",
        false,
        &[("Retry-After", "1".to_string())],
        b"{\"error\":\"connection limit reached\"}\n",
    );
    let _ = stream.write(&body);
}

// ---------------------------------------------------------------------------
// Response builders
// ---------------------------------------------------------------------------

/// Queue a fully serialized response; `Connection: close` responses
/// also stop further reads on the connection.
fn push_ready(conn: &mut Conn, keep_alive: bool, bytes: Vec<u8>) {
    conn.pending
        .push_back(Pending { keep_alive, body: PendingBody::Ready(bytes) });
    if !keep_alive {
        conn.close_after = true;
    }
}

/// A complete fixed-length response as bytes (writing into a `Vec`
/// cannot fail).
fn response_bytes(
    status: u16,
    reason: &str,
    content_type: &str,
    keep_alive: bool,
    extra: &[(&str, String)],
    body: &[u8],
) -> Vec<u8> {
    let mut buf = Vec::with_capacity(160 + body.len());
    let _ = http::write_response(
        &mut buf,
        status,
        reason,
        content_type,
        keep_alive,
        extra,
        body,
    );
    buf
}

/// `{"error": msg}` with the given status.
fn error_bytes(
    status: u16,
    reason: &str,
    keep_alive: bool,
    msg: &str,
) -> Vec<u8> {
    response_bytes(
        status,
        reason,
        "application/json",
        keep_alive,
        &[],
        format!("{{\"error\":{}}}\n", jstr(msg)).as_bytes(),
    )
}

/// 503 for a draining server/lane: retry after the drain deadline.
fn draining_bytes(tcfg: &TransportConfig, keep_alive: bool) -> Vec<u8> {
    let secs =
        (tcfg.drain_deadline().as_secs_f64().ceil() as u64).max(1);
    response_bytes(
        503,
        "Service Unavailable",
        "application/json",
        keep_alive,
        &[("Retry-After", secs.to_string())],
        b"{\"error\":\"draining: lane is closed to new requests\"}\n",
    )
}

// ---------------------------------------------------------------------------
// Inference payload parsing
// ---------------------------------------------------------------------------

/// Parse failure vs routing failure — distinct status codes.
enum InferReject {
    Malformed(String),
    UnknownLane(String),
}

/// Decode an inference payload: JSON (`{"lane": "...", "image":
/// [...]}`), or raw little-endian f32 bytes
/// (`Content-Type: application/octet-stream`) with the lane named in
/// the `X-Mpx-Lane` header or a `?lane=` query parameter.
fn parse_infer(
    req: &http::HttpRequest,
    routes: &HashMap<String, usize>,
    image_elems: usize,
) -> std::result::Result<(usize, Vec<f32>), InferReject> {
    let content_type = req.header("content-type").unwrap_or("application/json");
    let (lane_name, image): (String, Vec<f32>) =
        if content_type.starts_with("application/octet-stream") {
            let lane = req
                .header("x-mpx-lane")
                .or_else(|| req.query_param("lane"))
                .ok_or_else(|| {
                    InferReject::Malformed(
                        "binary payload needs an X-Mpx-Lane header or \
                         ?lane= query parameter"
                            .into(),
                    )
                })?;
            if req.body.len() % 4 != 0 {
                return Err(InferReject::Malformed(format!(
                    "binary image length {} is not a multiple of 4",
                    req.body.len()
                )));
            }
            let image = req
                .body
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            (lane.to_string(), image)
        } else {
            let text = std::str::from_utf8(&req.body).map_err(|_| {
                InferReject::Malformed("body is not utf-8".into())
            })?;
            let doc = Json::parse(text).map_err(|e| {
                InferReject::Malformed(format!("body is not JSON: {e}"))
            })?;
            let lane = doc
                .get("lane")
                .and_then(Json::as_str)
                .ok_or_else(|| {
                    InferReject::Malformed(
                        "missing string field \"lane\"".into(),
                    )
                })?
                .to_string();
            let arr = doc.get("image").and_then(Json::as_arr).ok_or_else(
                || InferReject::Malformed("missing array field \"image\"".into()),
            )?;
            let mut image = Vec::with_capacity(arr.len());
            for v in arr {
                image.push(v.as_f64().ok_or_else(|| {
                    InferReject::Malformed(
                        "\"image\" must contain only numbers".into(),
                    )
                })? as f32);
            }
            (lane, image)
        };
    let lane = *routes
        .get(lane_name.as_str())
        .ok_or(InferReject::UnknownLane(lane_name))?;
    if image.len() != image_elems {
        return Err(InferReject::Malformed(format!(
            "image has {} elements, lane expects {image_elems}",
            image.len()
        )));
    }
    Ok((lane, image))
}

// ---------------------------------------------------------------------------
// Serialization helpers
// ---------------------------------------------------------------------------

/// `s` as a JSON string literal (quotes included) — the crate's one
/// escaping implementation, shared with [`Json::dump`].
fn jstr(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    write_escaped(s, &mut out);
    out
}

/// The result line streamed back to the client.  Non-finite logits
/// serialize as `null` (JSON has no NaN/inf) — the `finite` flag is
/// the per-response overflow signal.
fn outcome_json(out: &Outcome, lane_name: &str) -> String {
    use std::fmt::Write;
    let mut s = String::with_capacity(96 + out.logits.len() * 12);
    let _ = write!(
        s,
        "{{\"id\":{},\"lane\":{},\"latency_us\":{},\
         \"missed_deadline\":{},\"finite\":{},\"logits\":[",
        out.id,
        jstr(lane_name),
        out.latency.as_micros(),
        out.missed_deadline,
        out.finite,
    );
    for (i, v) in out.logits.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        if v.is_finite() {
            let _ = write!(s, "{v}");
        } else {
            s.push_str("null");
        }
    }
    s.push_str("]}\n");
    s
}

fn healthz_json(
    shared: &Shared,
    sched: &Scheduler,
    lane_names: &[String],
) -> String {
    use std::fmt::Write;
    let pool = sched.counters();
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\"status\":\"{}\",\"pending_streams\":{},\
         \"workers\":{{\"live\":{},\"busy\":{}}},\"lanes\":[",
        if shared.is_draining() { "draining" } else { "ok" },
        shared.pending_streams(),
        pool.live,
        pool.busy,
    );
    for (i, name) in lane_names.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"name\":{},\"depth\":{},\"closed\":{}}}",
            jstr(name),
            sched.lane_depth(i),
            sched.lane_is_closed(i),
        );
    }
    s.push_str("]}\n");
    s
}

/// Serialize the live engine + transport state in Prometheus text
/// exposition format: admission counters and depth per lane, the
/// streamed-completion tallies (including the per-lane non-finite /
/// overflow counter), latency summaries from the per-lane
/// [`NamedHistograms`], worker-pool gauges, and the transport
/// totals (connection lifecycle, keep-alive reuse, deadline
/// evictions).
fn prometheus_text(
    shared: &Shared,
    sched: &Scheduler,
    lane_names: &[String],
) -> String {
    use std::fmt::Write;
    let mut s = String::with_capacity(4096);

    let gauge = |s: &mut String, name: &str, help: &str| {
        let _ = writeln!(s, "# HELP {name} {help}");
        let _ = writeln!(s, "# TYPE {name} gauge");
    };
    let counter = |s: &mut String, name: &str, help: &str| {
        let _ = writeln!(s, "# HELP {name} {help}");
        let _ = writeln!(s, "# TYPE {name} counter");
    };

    // Every label *value* below passes through `prom_escape` — lane
    // names come from config and may hold anything.
    let esc: Vec<String> =
        lane_names.iter().map(|n| crate::metrics::prom_escape(n)).collect();

    // Build + uptime identity, first so scrapers always see them.
    gauge(
        &mut s,
        "mpx_build_info",
        "build metadata as labels (value is constant 1)",
    );
    let _ = writeln!(
        s,
        "mpx_build_info{{version=\"{}\"}} 1",
        env!("CARGO_PKG_VERSION")
    );
    gauge(&mut s, "mpx_uptime_seconds", "seconds since server start");
    let _ = writeln!(s, "mpx_uptime_seconds {}", shared.clock.now().as_secs_f64());

    // Per-lane queue/admission state.
    counter(&mut s, "mpx_serve_accepted_total", "requests admitted per lane");
    for (i, name) in esc.iter().enumerate() {
        let q = sched.lane_stats(i);
        let _ = writeln!(
            s,
            "mpx_serve_accepted_total{{lane=\"{name}\"}} {}",
            q.accepted
        );
    }
    counter(&mut s, "mpx_serve_rejected_total", "admission rejections per lane");
    for (i, name) in esc.iter().enumerate() {
        let q = sched.lane_stats(i);
        let _ = writeln!(
            s,
            "mpx_serve_rejected_total{{lane=\"{name}\",reason=\"full\"}} {}",
            q.rejected - q.rejected_closed
        );
        let _ = writeln!(
            s,
            "mpx_serve_rejected_total{{lane=\"{name}\",reason=\"closed\"}} {}",
            q.rejected_closed
        );
    }
    gauge(&mut s, "mpx_serve_queue_depth", "queued requests per lane");
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_queue_depth{{lane=\"{name}\"}} {}",
            sched.lane_depth(i)
        );
    }
    gauge(&mut s, "mpx_serve_queue_peak_depth", "peak queue depth per lane");
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_queue_peak_depth{{lane=\"{name}\"}} {}",
            sched.lane_stats(i).peak_depth
        );
    }

    // Streamed-completion tallies + latency summaries.
    let (hists, tallies) = {
        let tallies = shared.tallies.lock().unwrap();
        let mut hists = NamedHistograms::new();
        for (i, t) in tallies.iter().enumerate() {
            hists.entry(&lane_names[i]).merge(&t.latency);
        }
        (hists, tallies.clone())
    };
    counter(&mut s, "mpx_serve_completed_total", "completions per lane");
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_completed_total{{lane=\"{name}\"}} {}",
            tallies[i].completed
        );
    }
    counter(
        &mut s,
        "mpx_serve_deadline_misses_total",
        "completions over their lane deadline",
    );
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_deadline_misses_total{{lane=\"{name}\"}} {}",
            tallies[i].deadline_misses
        );
    }
    counter(
        &mut s,
        "mpx_serve_nonfinite_total",
        "responses with a non-finite logit (half-precision overflow \
         accounting)",
    );
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_nonfinite_total{{lane=\"{name}\"}} {}",
            tallies[i].nonfinite
        );
    }
    hists.to_prometheus("mpx_serve_latency_seconds", &mut s);

    // Worker pool.
    let pool = sched.counters();
    gauge(&mut s, "mpx_serve_workers", "worker pool state");
    let _ = writeln!(s, "mpx_serve_workers{{state=\"live\"}} {}", pool.live);
    let _ = writeln!(s, "mpx_serve_workers{{state=\"busy\"}} {}", pool.busy);
    counter(&mut s, "mpx_serve_workers_spawned_total", "workers ever spawned");
    let _ = writeln!(s, "mpx_serve_workers_spawned_total {}", pool.spawned);

    // The planner loop: live replans adopted, and the service model
    // each lane's current plan was sized against.
    counter(
        &mut s,
        "mpx_serve_replans_total",
        "live bucket replans adopted by the scheduler",
    );
    let _ = writeln!(s, "mpx_serve_replans_total {}", sched.replans());
    gauge(
        &mut s,
        "mpx_serve_service_model",
        "per-lane linear service model behind the current plan \
         (microseconds; param=\"overhead_us\"|\"per_row_us\")",
    );
    for (name, (overhead, per_row)) in esc.iter().zip(sched.lane_models()) {
        let _ = writeln!(
            s,
            "mpx_serve_service_model{{lane=\"{name}\",param=\"overhead_us\"}} \
             {overhead}"
        );
        let _ = writeln!(
            s,
            "mpx_serve_service_model{{lane=\"{name}\",param=\"per_row_us\"}} \
             {per_row}"
        );
    }

    // Transport totals.
    let c = shared.counter_snapshot();
    counter(&mut s, "mpx_transport_connections_total", "accepted connections");
    let _ = writeln!(s, "mpx_transport_connections_total {}", c.connections);
    gauge(
        &mut s,
        "mpx_transport_connections_open",
        "connections currently owned by the reactor",
    );
    let _ = writeln!(
        s,
        "mpx_transport_connections_open {}",
        shared.open_conns.load(Ordering::SeqCst)
    );
    counter(
        &mut s,
        "mpx_transport_requests_total",
        "HTTP requests parsed across all connections",
    );
    let _ = writeln!(s, "mpx_transport_requests_total {}", c.requests);
    counter(
        &mut s,
        "mpx_transport_keepalive_reuses_total",
        "requests beyond the first on their connection",
    );
    let _ = writeln!(
        s,
        "mpx_transport_keepalive_reuses_total {}",
        c.keepalive_reuses
    );
    gauge(
        &mut s,
        "mpx_transport_keepalive_requests_per_connection",
        "mean requests served per accepted connection",
    );
    let _ = writeln!(
        s,
        "mpx_transport_keepalive_requests_per_connection {}",
        c.requests as f64 / c.connections.max(1) as f64
    );
    counter(
        &mut s,
        "mpx_transport_read_deadline_evictions_total",
        "connections evicted with 408 at a read/request deadline",
    );
    let _ = writeln!(
        s,
        "mpx_transport_read_deadline_evictions_total {}",
        c.deadline_evictions
    );
    counter(&mut s, "mpx_transport_admitted_total", "requests admitted");
    let _ = writeln!(s, "mpx_transport_admitted_total {}", c.admitted);
    counter(
        &mut s,
        "mpx_transport_streamed_total",
        "completions delivered to a live client",
    );
    let _ = writeln!(s, "mpx_transport_streamed_total {}", c.streamed);
    counter(&mut s, "mpx_transport_rejected_total", "rejections by reason");
    for (reason, v) in [
        ("queue_full", c.rejected_full),
        ("draining", c.rejected_draining),
        ("unknown_lane", c.unknown_lane),
        ("malformed", c.malformed),
        ("overloaded", c.overloaded),
    ] {
        let _ = writeln!(
            s,
            "mpx_transport_rejected_total{{reason=\"{reason}\"}} {v}"
        );
    }
    counter(
        &mut s,
        "mpx_transport_disconnects_total",
        "clients gone before their result",
    );
    let _ = writeln!(s, "mpx_transport_disconnects_total {}", c.disconnects);
    counter(
        &mut s,
        "mpx_transport_drain_abandoned_total",
        "streams abandoned at the drain deadline",
    );
    let _ =
        writeln!(s, "mpx_transport_drain_abandoned_total {}", c.drain_abandoned);
    gauge(&mut s, "mpx_transport_pending_streams", "streams awaiting results");
    let _ = writeln!(
        s,
        "mpx_transport_pending_streams {}",
        shared.pending_streams()
    );
    gauge(&mut s, "mpx_transport_draining", "1 while draining");
    let _ = writeln!(
        s,
        "mpx_transport_draining {}",
        u8::from(shared.is_draining())
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_suffix_extracts_the_alias() {
        assert_eq!(lane_suffix("vit_tiny/chat"), Some("chat"));
        assert_eq!(lane_suffix("chat"), None);
        assert_eq!(lane_suffix("trailing/"), None);
        assert_eq!(lane_suffix("a/b/c"), Some("c"));
    }

    #[test]
    fn jstr_produces_quoted_escaped_literals() {
        assert_eq!(jstr("plain"), "\"plain\"");
        assert_eq!(jstr("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(jstr("\u{1}"), "\"\\u0001\"");
    }

    #[test]
    fn outcome_json_is_valid_json_even_with_nonfinite_logits() {
        let out = Outcome {
            id: 3,
            lane: 0,
            latency: Duration::from_micros(1500),
            missed_deadline: false,
            finite: false,
            logits: vec![1.0, f32::NAN, f32::INFINITY],
        };
        let line = outcome_json(&out, "vit_tiny/chat");
        let doc = Json::parse(line.trim()).unwrap();
        assert_eq!(doc.get("id").and_then(Json::as_i64), Some(3));
        assert_eq!(doc.get("finite").and_then(Json::as_bool), Some(false));
        let logits = doc.get("logits").and_then(Json::as_arr).unwrap();
        assert_eq!(logits.len(), 3);
        assert_eq!(logits[1], Json::Null);
    }

    #[test]
    fn response_bytes_honors_keep_alive() {
        let ka = response_bytes(
            200,
            "OK",
            "application/json",
            true,
            &[],
            b"{}\n",
        );
        let text = String::from_utf8(ka).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        let close = error_bytes(400, "Bad Request", false, "nope");
        let text = String::from_utf8(close).unwrap();
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.contains("\"error\":\"nope\""), "{text}");
    }
}
