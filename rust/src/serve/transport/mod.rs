//! Network transport for `mpx serve` — a dependency-light threaded
//! HTTP/1.1 server that turns the in-process serving engine
//! ([`crate::serve`]) into a real service, plus the std-only
//! [`client`] the load generator and the integration tests drive it
//! with.
//!
//! ```text
//!   client ──POST /v1/infer──▶ acceptor ──▶ handler thread
//!                                               │ parse + route (lane)
//!                                               ▼
//!                                   Scheduler::submit (per-lane queue)
//!                 admitted │ full │ closed │ unknown │ malformed
//!                   200    │ 429  │  503   │  404    │   400
//!                 chunked  ▲
//!                 stream   │ CompletionFn (worker thread, the moment
//!                          │ continuous batching frees the slot)
//! ```
//!
//! Semantics, mapped faithfully onto HTTP:
//!
//! * **Streaming, not polling** — an admitted request gets its
//!   response headers and a `queued` ack chunk immediately, then its
//!   result chunk the instant its batch completes (per-request
//!   [`Completion`] callbacks, chunked transfer encoding).  There is
//!   no batch-granularity blocking anywhere on the response path.
//! * **Admission control is the status code** — a full lane queue is
//!   `429 Too Many Requests` with `Retry-After` derived from that
//!   lane's (planner-chosen) flush timeout; a closed/draining lane is
//!   `503 Service Unavailable`; an unknown lane is `404`; an
//!   unparsable payload is `400`.
//! * **Overflow accounting is per response** (Zhao et al., adaptive
//!   loss scaling: keep the numerics observable end-to-end): every
//!   result reports `finite` — whether the half-precision forward
//!   produced any non-finite logit — and `/metrics` exports the
//!   per-lane `nonfinite` counter next to the latency summaries.
//! * **Graceful drain** — shutdown (SIGINT via [`install_sigint`], or
//!   [`ServerHandle::shutdown`]) stops admitting (`503`), closes the
//!   lanes so workers flush everything queued, keeps serving
//!   `/healthz`+`/metrics`, and exits once every pending stream
//!   flushed or `drain_deadline_ms` passed — abandoned streams get an
//!   error chunk, and nothing leaks: the pending-stream registry and
//!   the worker slots both drain to zero.
//!
//! One request per connection (`Connection: close`): inference
//! responses are streams, so connection reuse would serialize a
//! caller's requests behind its slowest completion anyway.  The
//! worker pool is fixed at the configured size — autoscaling hooks
//! into the load-generator engine's arrival loop, not the socket
//! path, and is a transport follow-up.
//!
//! Everything here is std-only and runs without the `xla` feature:
//! `rust/tests/serve_transport.rs` drives a real socket against a
//! stub executor, exactly like `examples/serve_http.rs`.

pub mod client;
pub mod http;

use std::collections::HashMap;
use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::TransportConfig;
use crate::metrics::{LatencyHistogram, NamedHistograms};
use crate::serve::batcher::SchedPolicy;
use crate::serve::clock::{Clock, WallClock};
use crate::serve::queue::{QueueStats, Request};
use crate::serve::sched::{
    AutoscalePolicy, Completion, CompletionFn, LaneSpec, PoolCounters,
    Scheduler,
};
use crate::serve::worker::{worker_loop, BatchExecutor, WorkerReport};
use crate::trace::{chrome, Span, SpanKind, TraceConfig, Tracer};
use crate::util::human_duration;
use crate::util::json::{write_escaped, Json};

// ---------------------------------------------------------------------------
// SIGINT → graceful drain
// ---------------------------------------------------------------------------

static SIGINT_FLAG: AtomicBool = AtomicBool::new(false);

/// Install a process-wide SIGINT handler that requests a graceful
/// drain of every running [`Server`] (stop accepting new inference,
/// flush the lanes, then exit).  Pure-std via the libc `signal`
/// symbol that is always linked on unix; a no-op elsewhere.  The
/// handler only sets an atomic flag — the acceptor loop polls it.
#[cfg(unix)]
pub fn install_sigint() {
    extern "C" fn on_sigint(_sig: i32) {
        SIGINT_FLAG.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }
    unsafe {
        signal(2 /* SIGINT */, on_sigint);
    }
}

#[cfg(not(unix))]
pub fn install_sigint() {}

/// Whether SIGINT has been received since [`install_sigint`].
pub fn sigint_requested() -> bool {
    SIGINT_FLAG.load(Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// Shared server state
// ---------------------------------------------------------------------------

/// What a handler thread receives when its request's batch completes.
struct Outcome {
    id: u64,
    latency: Duration,
    missed_deadline: bool,
    finite: bool,
    logits: Vec<f32>,
}

/// Transport-level counters.  Plain totals since server start; the
/// per-lane engine accounting lives in the queue stats and
/// [`StreamTally`]s.
#[derive(Default)]
struct Counters {
    connections: AtomicU64,
    admitted: AtomicU64,
    streamed: AtomicU64,
    rejected_full: AtomicU64,
    rejected_draining: AtomicU64,
    unknown_lane: AtomicU64,
    malformed: AtomicU64,
    overloaded: AtomicU64,
    disconnects: AtomicU64,
    drain_abandoned: AtomicU64,
    nonfinite: AtomicU64,
}

/// Owned snapshot of the transport counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CounterSnapshot {
    /// Accepted TCP connections.
    pub connections: u64,
    /// Requests admitted into a lane queue.
    pub admitted: u64,
    /// Completions delivered to a live client stream.
    pub streamed: u64,
    /// `429` responses (lane queue full).
    pub rejected_full: u64,
    /// `503` responses (lane closed / draining).
    pub rejected_draining: u64,
    /// `404` responses (no such lane).
    pub unknown_lane: u64,
    /// `400` responses (unparsable request).
    pub malformed: u64,
    /// Connections turned away at the `max_connections` cap (`503`).
    pub overloaded: u64,
    /// Streams whose client vanished before (or while) the result
    /// was written; the engine slot was freed and the completion
    /// accounted regardless.
    pub disconnects: u64,
    /// Streams abandoned at the drain deadline (error chunk sent).
    pub drain_abandoned: u64,
    /// Responses containing a non-finite logit (overflow accounting,
    /// also available per lane in `/metrics`).
    pub nonfinite: u64,
}

/// Retained-sample bound for each lane's latency histogram: a
/// long-running server keeps memory `O(cap)` per lane via
/// [`LatencyHistogram::with_sample_cap`]'s deterministic
/// stride-doubling reservoir, while `_count`/`_sum`/`max` stay exact
/// running counters.
const LATENCY_SAMPLE_CAP: usize = 16_384;

/// Per-lane completion accounting on the transport side (what the
/// scheduler streamed to clients), feeding `/metrics` and the final
/// [`TransportReport`].
#[derive(Debug, Clone)]
struct StreamTally {
    completed: u64,
    deadline_misses: u64,
    nonfinite: u64,
    latency: LatencyHistogram,
}

impl Default for StreamTally {
    fn default() -> Self {
        StreamTally {
            completed: 0,
            deadline_misses: 0,
            nonfinite: 0,
            latency: LatencyHistogram::with_sample_cap(LATENCY_SAMPLE_CAP),
        }
    }
}

struct Shared {
    clock: Arc<WallClock>,
    /// Drain requested (SIGINT or handle): stop admitting inference.
    shutdown: AtomicBool,
    /// When the drain started (clock offset), once it has.
    drain_started: Mutex<Option<Duration>>,
    /// A worker died: pending streams error out instead of waiting.
    failed: AtomicBool,
    /// request id → the handler thread waiting to stream its result.
    slots: Mutex<HashMap<u64, mpsc::Sender<Outcome>>>,
    next_id: AtomicU64,
    active_conns: AtomicUsize,
    counters: Counters,
    tallies: Mutex<Vec<StreamTally>>,
}

impl Shared {
    fn new() -> Shared {
        Shared {
            clock: Arc::new(WallClock::new()),
            shutdown: AtomicBool::new(false),
            drain_started: Mutex::new(None),
            failed: AtomicBool::new(false),
            slots: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            active_conns: AtomicUsize::new(0),
            counters: Counters::default(),
            tallies: Mutex::new(Vec::new()),
        }
    }

    fn counter_snapshot(&self) -> CounterSnapshot {
        let c = &self.counters;
        let ld = Ordering::Relaxed;
        CounterSnapshot {
            connections: c.connections.load(ld),
            admitted: c.admitted.load(ld),
            streamed: c.streamed.load(ld),
            rejected_full: c.rejected_full.load(ld),
            rejected_draining: c.rejected_draining.load(ld),
            unknown_lane: c.unknown_lane.load(ld),
            malformed: c.malformed.load(ld),
            overloaded: c.overloaded.load(ld),
            disconnects: c.disconnects.load(ld),
            drain_abandoned: c.drain_abandoned.load(ld),
            nonfinite: c.nonfinite.load(ld),
        }
    }

    fn pending_streams(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    fn register(&self, id: u64) -> mpsc::Receiver<Outcome> {
        let (tx, rx) = mpsc::channel();
        self.slots.lock().unwrap().insert(id, tx);
        rx
    }

    fn deregister(&self, id: u64) {
        self.slots.lock().unwrap().remove(&id);
    }

    fn is_draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || sigint_requested()
    }

    /// The scheduler's streaming callback: account the completion per
    /// lane, then hand the result to the waiting handler (if its
    /// client is still around).  Runs on the completing worker's
    /// thread, outside all scheduler locks.
    fn on_completion(&self, c: &Completion) {
        let finite = c.output.iter().all(|v| v.is_finite());
        {
            let mut tallies = self.tallies.lock().unwrap();
            let t = &mut tallies[c.lane];
            t.completed += 1;
            if c.missed_deadline {
                t.deadline_misses += 1;
            }
            if !finite {
                t.nonfinite += 1;
            }
            t.latency.record(c.latency);
        }
        if !finite {
            self.counters.nonfinite.fetch_add(1, Ordering::Relaxed);
        }
        let tx = self.slots.lock().unwrap().remove(&c.request.id);
        if let Some(tx) = tx {
            // Delivery (and the streamed/disconnect accounting) is
            // the handler thread's job — it owns the socket and is
            // the only side that can tell a live client from a dead
            // one.
            let _ = tx.send(Outcome {
                id: c.request.id,
                latency: c.latency,
                missed_deadline: c.missed_deadline,
                finite,
                logits: c.output.to_vec(),
            });
        }
    }
}

/// Cloneable control handle: request a drain, watch the live state.
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Request a graceful drain: stop admitting, flush the lanes,
    /// let [`Server::run`] return.  Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.shared.is_draining()
    }

    /// Streams admitted but not yet answered (the completion
    /// registry's size) — zero after a clean drain.
    pub fn pending_streams(&self) -> usize {
        self.shared.pending_streams()
    }

    pub fn counters(&self) -> CounterSnapshot {
        self.shared.counter_snapshot()
    }
}

// ---------------------------------------------------------------------------
// Reports
// ---------------------------------------------------------------------------

/// One lane's transport-side slice of the run.
#[derive(Debug, Clone)]
pub struct LaneStreamReport {
    pub name: String,
    pub completed: u64,
    pub deadline_misses: u64,
    /// Completions containing a non-finite logit (overflow counter).
    pub nonfinite: u64,
    pub queue: QueueStats,
    pub latency: LatencyHistogram,
}

/// What [`Server::run`] returns after the drain finishes.
#[derive(Debug)]
pub struct TransportReport {
    pub wall: Duration,
    pub counters: CounterSnapshot,
    /// Registry entries left after drain — zero unless something
    /// leaked (asserted in the integration tests).
    pub pending_streams: usize,
    /// Final pool counters — `busy == 0` after a clean drain.
    pub pool: PoolCounters,
    pub lanes: Vec<LaneStreamReport>,
    pub workers: Vec<WorkerReport>,
    /// Tracer snapshot at drain (empty when tracing was off) — what
    /// `GET /debug/trace` would have returned at the end.
    pub spans: Vec<Span>,
    /// Spans the tracer's ring dropped (oldest first).
    pub trace_dropped: u64,
}

impl TransportReport {
    pub fn print(&self) {
        let c = &self.counters;
        println!(
            "[serve/transport] {} connections, {} admitted, {} streamed, \
             {} disconnects | rejected: {} full, {} draining, {} unknown \
             lane, {} malformed, {} overloaded | wall {}",
            c.connections,
            c.admitted,
            c.streamed,
            c.disconnects,
            c.rejected_full,
            c.rejected_draining,
            c.unknown_lane,
            c.malformed,
            c.overloaded,
            human_duration(self.wall),
        );
        for lane in &self.lanes {
            let p99 = lane
                .latency
                .quantile(0.99)
                .map(human_duration)
                .unwrap_or_else(|| "-".into());
            println!(
                "        lane {}: {} completed, {} misses, {} non-finite, \
                 {} rejected, p99 {}",
                lane.name,
                lane.completed,
                lane.deadline_misses,
                lane.nonfinite,
                lane.queue.rejected,
                p99,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------------

/// A bound listener, ready to [`run`](Server::run).  Binding is
/// separate from running so callers learn the ephemeral port (tests
/// bind `127.0.0.1:0`) and can clone a [`ServerHandle`] before the
/// accept loop takes the thread.
pub struct Server {
    listener: TcpListener,
    local: SocketAddr,
    tcfg: TransportConfig,
    trace: TraceConfig,
    shared: Arc<Shared>,
}

impl Server {
    pub fn bind(tcfg: &TransportConfig) -> Result<Server> {
        tcfg.validate()?;
        let listener = TcpListener::bind(&tcfg.addr)
            .with_context(|| format!("bind {}", tcfg.addr))?;
        // Non-blocking accept: the acceptor polls shutdown between
        // accepts instead of parking in the kernel forever.
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        Ok(Server {
            listener,
            local,
            tcfg: tcfg.clone(),
            trace: TraceConfig::default(),
            shared: Arc::new(Shared::new()),
        })
    }

    /// Enable span tracing for the run (the `[trace]` table); spans
    /// become visible at `GET /debug/trace` and in the final
    /// [`TransportReport`].  Call before [`run`](Server::run).
    pub fn set_trace(&mut self, trace: TraceConfig) {
        self.trace = trace;
    }

    /// The actually-bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: self.shared.clone() }
    }

    /// Serve until a drain is requested ([`ServerHandle::shutdown`]
    /// or SIGINT after [`install_sigint`]) and completes.  Blocks the
    /// calling thread: it becomes the acceptor; `workers` executor
    /// threads and one handler thread per live connection are spawned
    /// inside.  `make_executor(worker, lane)` runs on the worker's
    /// own thread (PJRT literals are thread-local);
    /// `image_elems` is the flattened input row length every lane
    /// accepts (payloads of any other size are `400`-rejected before
    /// they can reach an executor).
    pub fn run<E, F>(
        self,
        lanes: Vec<LaneSpec>,
        workers: usize,
        policy: SchedPolicy,
        image_elems: usize,
        make_executor: F,
    ) -> Result<TransportReport>
    where
        E: BatchExecutor,
        F: Fn(usize, usize) -> Result<E> + Sync,
    {
        let shared = self.shared;
        let tcfg = self.tcfg;
        let nlanes = lanes.len();
        anyhow::ensure!(nlanes > 0, "transport: no lanes");
        anyhow::ensure!(workers > 0, "transport: no workers");
        *shared.tallies.lock().unwrap() =
            vec![StreamTally::default(); nlanes];

        // Routing table: full lane names always route.  The suffix
        // after the last '/' ("chat" for "vit_tiny/chat") routes too,
        // but only when it is unambiguous — shared by no other lane's
        // suffix and not itself some lane's full name (a full-name
        // route is never shadowed or removed by suffix handling).
        let mut routes: HashMap<String, usize> = HashMap::new();
        for (i, spec) in lanes.iter().enumerate() {
            routes.insert(spec.name.clone(), i);
        }
        for (i, spec) in lanes.iter().enumerate() {
            let Some(suffix) = lane_suffix(&spec.name) else {
                continue;
            };
            let shared_suffix = lanes.iter().enumerate().any(|(j, other)| {
                j != i && lane_suffix(&other.name) == Some(suffix)
            });
            if !shared_suffix && !routes.contains_key(suffix) {
                routes.insert(suffix.to_string(), i);
            }
        }
        let lane_names: Vec<String> =
            lanes.iter().map(|s| s.name.clone()).collect();
        let deadlines: Vec<Duration> =
            lanes.iter().map(|s| s.deadline).collect();
        // 429 Retry-After: one flush window is how long it takes the
        // planner's dispatch policy to clear a sub-bucket backlog, so
        // it is the honest "when is a slot likely free" hint.
        let retry_after: Vec<u64> = lanes
            .iter()
            .map(|s| (s.batcher.flush_timeout.as_secs_f64().ceil() as u64).max(1))
            .collect();

        let cb_shared = shared.clone();
        let on_complete: Box<CompletionFn> =
            Box::new(move |c: &Completion| cb_shared.on_completion(c));
        let clock: Arc<dyn Clock> = shared.clock.clone();
        let tracer = Tracer::from_config(clock.clone(), &self.trace);
        let mut sched = Scheduler::new(
            lanes,
            policy,
            AutoscalePolicy::fixed(workers),
            clock,
            Some(on_complete),
        )?;
        if let Some(t) = &tracer {
            sched.set_tracer(t.clone());
        }
        let sched = Arc::new(sched);

        let t_start = shared.clock.now();
        let ready = std::sync::Barrier::new(workers + 1);
        let listener = self.listener;

        let worker_reports = std::thread::scope(|scope| {
            let sched: &Scheduler = &sched;
            let shared: &Shared = &shared;
            let make_executor = &make_executor;
            let ready = &ready;
            let tcfg = &tcfg;
            let routes = &routes;
            let lane_names = &lane_names;
            let deadlines = &deadlines;
            let retry_after = &retry_after;

            sched.register_workers(workers);
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    scope.spawn(move || {
                        let execs: Result<Vec<E>> = (0..nlanes)
                            .map(|lane| make_executor(w, lane))
                            .collect();
                        // Pass the barrier success or not, or bind
                        // would wedge below.
                        ready.wait();
                        let out = match execs {
                            Ok(mut execs) => worker_loop(
                                w,
                                &mut execs,
                                sched,
                                &*shared.clock,
                            ),
                            Err(e) => {
                                sched.worker_aborted();
                                Err(e)
                            }
                        };
                        if out.is_err() {
                            // A dead worker drains the server: stop
                            // admitting, error the pending streams.
                            shared.failed.store(true, Ordering::SeqCst);
                            shared.shutdown.store(true, Ordering::SeqCst);
                            sched.close_all();
                        }
                        out
                    })
                })
                .collect();
            ready.wait();

            // ----- acceptor loop (this thread) -----
            let mut drain_closed = false;
            loop {
                if shared.is_draining() {
                    shared.shutdown.store(true, Ordering::SeqCst);
                    if !drain_closed {
                        *shared.drain_started.lock().unwrap() =
                            Some(shared.clock.now());
                        sched.close_all();
                        drain_closed = true;
                    }
                    let started =
                        shared.drain_started.lock().unwrap().unwrap();
                    let deadline_passed = shared.clock.now()
                        > started + tcfg.drain_deadline();
                    // Keep accepting during the drain (new inference
                    // gets an orderly 503; /healthz and /metrics keep
                    // answering) until the pending streams flush.
                    if shared.pending_streams() == 0 || deadline_passed {
                        break;
                    }
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        shared
                            .counters
                            .connections
                            .fetch_add(1, Ordering::Relaxed);
                        if shared.active_conns.load(Ordering::SeqCst)
                            >= tcfg.max_connections
                        {
                            shared
                                .counters
                                .overloaded
                                .fetch_add(1, Ordering::Relaxed);
                            let _ = turn_away(stream);
                            continue;
                        }
                        shared.active_conns.fetch_add(1, Ordering::SeqCst);
                        scope.spawn(move || {
                            handle_connection(
                                stream,
                                shared,
                                sched,
                                tcfg,
                                routes,
                                lane_names,
                                deadlines,
                                retry_after,
                                image_elems,
                            );
                            shared
                                .active_conns
                                .fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => {
                        // Transient accept failure (EMFILE, reset):
                        // back off and keep serving.
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            }

            handles
                .into_iter()
                .map(|h| h.join().expect("transport worker panicked"))
                .collect::<Result<Vec<_>>>()
        })?;

        let wall = shared.clock.now().saturating_sub(t_start);
        let tallies = std::mem::take(&mut *shared.tallies.lock().unwrap());
        let lanes = tallies
            .into_iter()
            .enumerate()
            .map(|(i, t)| LaneStreamReport {
                name: lane_names[i].clone(),
                completed: t.completed,
                deadline_misses: t.deadline_misses,
                nonfinite: t.nonfinite,
                queue: sched.lane_stats(i),
                latency: t.latency,
            })
            .collect();
        let (spans, trace_dropped) = match &tracer {
            Some(t) => (t.snapshot(), t.dropped()),
            None => (Vec::new(), 0),
        };
        Ok(TransportReport {
            wall,
            counters: shared.counter_snapshot(),
            pending_streams: shared.pending_streams(),
            pool: sched.counters(),
            lanes,
            workers: worker_reports,
            spans,
            trace_dropped,
        })
    }
}

/// The short routing alias of a lane name: the part after the last
/// `/` ("chat" for "vit_tiny/chat"); `None` when there is no slash.
fn lane_suffix(name: &str) -> Option<&str> {
    let s = name.rsplit('/').next().unwrap_or("");
    (!s.is_empty() && s != name).then_some(s)
}

/// Over the connection cap: answer 503 without reading the request.
fn turn_away(mut stream: TcpStream) -> io::Result<()> {
    http::write_response(
        &mut stream,
        503,
        "Service Unavailable",
        "application/json",
        &[("Retry-After", "1".to_string())],
        b"{\"error\":\"connection limit reached\"}\n",
    )
}

// ---------------------------------------------------------------------------
// Per-connection handling
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn handle_connection(
    mut stream: TcpStream,
    shared: &Shared,
    sched: &Scheduler,
    tcfg: &TransportConfig,
    routes: &HashMap<String, usize>,
    lane_names: &[String],
    deadlines: &[Duration],
    retry_after: &[u64],
    image_elems: usize,
) {
    // Accepted sockets inherit O_NONBLOCK from the listener on some
    // platforms — make blocking-with-timeout explicit.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(tcfg.read_timeout()));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let req = match http::read_request(&mut reader, &mut stream) {
        Ok(Some(req)) => req,
        Ok(None) => return, // connected and left without a request
        Err(http::HttpError::Io(_)) => return, // timeout / reset
        Err(e) => {
            shared.counters.malformed.fetch_add(1, Ordering::Relaxed);
            let _ = reject(&mut stream, 400, "Bad Request", &e.to_string());
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let body = healthz_json(shared, sched, lane_names);
            let _ = http::write_response(
                &mut stream,
                200,
                "OK",
                "application/json",
                &[],
                body.as_bytes(),
            );
        }
        ("GET", "/metrics") => {
            let body = prometheus_text(shared, sched, lane_names);
            let _ = http::write_response(
                &mut stream,
                200,
                "OK",
                "text/plain; version=0.0.4",
                &[],
                body.as_bytes(),
            );
        }
        ("GET", "/debug/trace") => match sched.tracer() {
            Some(t) => {
                // The ring's whole content (the last `buffer_spans`
                // recorded), as a Chrome trace document — save the
                // body to a file and load it in Perfetto as-is.
                let doc = chrome::chrome_trace(&t.snapshot(), t.dropped());
                let _ = http::write_response(
                    &mut stream,
                    200,
                    "OK",
                    "application/json",
                    &[],
                    (doc.dump() + "\n").as_bytes(),
                );
            }
            None => {
                let _ = reject(
                    &mut stream,
                    404,
                    "Not Found",
                    "tracing is disabled ([trace] enabled = false)",
                );
            }
        },
        ("POST", "/v1/infer") => {
            handle_infer(
                stream, &req, shared, sched, tcfg, routes, lane_names,
                deadlines, retry_after, image_elems,
            );
        }
        _ => {
            let _ = reject(
                &mut stream,
                404,
                "Not Found",
                &format!("no endpoint {} {}", req.method, req.path),
            );
        }
    }
}

/// Parse failure vs routing failure — distinct status codes.
enum InferReject {
    Malformed(String),
    UnknownLane(String),
}

/// Decode an inference payload: JSON (`{"lane": "...", "image":
/// [...]}`), or raw little-endian f32 bytes
/// (`Content-Type: application/octet-stream`) with the lane named in
/// the `X-Mpx-Lane` header or a `?lane=` query parameter.
fn parse_infer(
    req: &http::HttpRequest,
    routes: &HashMap<String, usize>,
    image_elems: usize,
) -> std::result::Result<(usize, Vec<f32>), InferReject> {
    let content_type = req.header("content-type").unwrap_or("application/json");
    let (lane_name, image): (String, Vec<f32>) =
        if content_type.starts_with("application/octet-stream") {
            let lane = req
                .header("x-mpx-lane")
                .or_else(|| req.query_param("lane"))
                .ok_or_else(|| {
                    InferReject::Malformed(
                        "binary payload needs an X-Mpx-Lane header or \
                         ?lane= query parameter"
                            .into(),
                    )
                })?;
            if req.body.len() % 4 != 0 {
                return Err(InferReject::Malformed(format!(
                    "binary image length {} is not a multiple of 4",
                    req.body.len()
                )));
            }
            let image = req
                .body
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            (lane.to_string(), image)
        } else {
            let text = std::str::from_utf8(&req.body).map_err(|_| {
                InferReject::Malformed("body is not utf-8".into())
            })?;
            let doc = Json::parse(text).map_err(|e| {
                InferReject::Malformed(format!("body is not JSON: {e}"))
            })?;
            let lane = doc
                .get("lane")
                .and_then(Json::as_str)
                .ok_or_else(|| {
                    InferReject::Malformed(
                        "missing string field \"lane\"".into(),
                    )
                })?
                .to_string();
            let arr = doc.get("image").and_then(Json::as_arr).ok_or_else(
                || InferReject::Malformed("missing array field \"image\"".into()),
            )?;
            let mut image = Vec::with_capacity(arr.len());
            for v in arr {
                image.push(v.as_f64().ok_or_else(|| {
                    InferReject::Malformed(
                        "\"image\" must contain only numbers".into(),
                    )
                })? as f32);
            }
            (lane, image)
        };
    let lane = *routes
        .get(lane_name.as_str())
        .ok_or(InferReject::UnknownLane(lane_name))?;
    if image.len() != image_elems {
        return Err(InferReject::Malformed(format!(
            "image has {} elements, lane expects {image_elems}",
            image.len()
        )));
    }
    Ok((lane, image))
}

#[allow(clippy::too_many_arguments)]
fn handle_infer(
    mut stream: TcpStream,
    req: &http::HttpRequest,
    shared: &Shared,
    sched: &Scheduler,
    tcfg: &TransportConfig,
    routes: &HashMap<String, usize>,
    lane_names: &[String],
    deadlines: &[Duration],
    retry_after: &[u64],
    image_elems: usize,
) {
    let (lane, image) = match parse_infer(req, routes, image_elems) {
        Ok(ok) => ok,
        Err(InferReject::Malformed(msg)) => {
            shared.counters.malformed.fetch_add(1, Ordering::Relaxed);
            let _ = reject(&mut stream, 400, "Bad Request", &msg);
            return;
        }
        Err(InferReject::UnknownLane(name)) => {
            shared.counters.unknown_lane.fetch_add(1, Ordering::Relaxed);
            let _ = reject(
                &mut stream,
                404,
                "Not Found",
                &format!(
                    "unknown lane {name:?} (serving: {})",
                    lane_names.join(", ")
                ),
            );
            return;
        }
    };

    // Draining: an orderly 503 before touching the queue.
    if shared.is_draining() {
        shared.counters.rejected_draining.fetch_add(1, Ordering::Relaxed);
        let _ = reject_draining(&mut stream, tcfg);
        return;
    }

    let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
    let rx = shared.register(id);
    let request =
        Request::new(id, image, deadlines[lane], shared.clock.now());
    if !sched.submit(lane, request) {
        shared.deregister(id);
        if sched.lane_is_closed(lane) {
            shared
                .counters
                .rejected_draining
                .fetch_add(1, Ordering::Relaxed);
            let _ = reject_draining(&mut stream, tcfg);
        } else {
            shared.counters.rejected_full.fetch_add(1, Ordering::Relaxed);
            let msg =
                format!("lane {} queue is full", lane_names[lane]);
            let _ = http::write_response(
                &mut stream,
                429,
                "Too Many Requests",
                "application/json",
                &[("Retry-After", retry_after[lane].to_string())],
                format!(
                    "{{\"error\":{},\"retry_after_s\":{}}}\n",
                    jstr(&msg),
                    retry_after[lane]
                )
                .as_bytes(),
            );
        }
        return;
    }
    shared.counters.admitted.fetch_add(1, Ordering::Relaxed);

    // Admitted: headers + ack chunk now, result chunk on completion.
    let ack = format!(
        "{{\"status\":\"queued\",\"id\":{id},\"lane\":{}}}\n",
        jstr(&lane_names[lane])
    );
    if http::start_chunked(
        &mut stream,
        200,
        "OK",
        "application/x-ndjson",
        &[],
    )
    .and_then(|()| http::write_chunk(&mut stream, ack.as_bytes()))
    .is_err()
    {
        // Client vanished between admission and headers.  The engine
        // still owns the request and will complete (and account) it;
        // nothing waits on the registry entry once we drop it.
        shared.deregister(id);
        shared.counters.disconnects.fetch_add(1, Ordering::Relaxed);
        return;
    }

    // Wait for the completion, polling the failure/drain state.
    loop {
        match rx.recv_timeout(Duration::from_millis(25)) {
            Ok(outcome) => {
                let egress_start = shared.clock.now();
                let body = outcome_json(&outcome, &lane_names[lane]);
                let delivered = !peer_closed(&stream)
                    && http::write_chunk(&mut stream, body.as_bytes())
                        .and_then(|()| http::finish_chunked(&mut stream))
                        .is_ok();
                if let Some(t) = sched.tracer() {
                    // Serialization + socket write of the result
                    // chunk — the only transport-side latency a
                    // client sees beyond the engine's service span.
                    t.record(
                        SpanKind::Egress,
                        egress_start,
                        shared.clock.now(),
                        lane as u64,
                        outcome.id,
                        0,
                    );
                }
                if delivered {
                    shared.counters.streamed.fetch_add(1, Ordering::Relaxed);
                } else {
                    shared
                        .counters
                        .disconnects
                        .fetch_add(1, Ordering::Relaxed);
                }
                return;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shared.failed.load(Ordering::SeqCst) {
                    shared.deregister(id);
                    shared
                        .counters
                        .drain_abandoned
                        .fetch_add(1, Ordering::Relaxed);
                    let _ = stream_error(&mut stream, id, "worker failed");
                    return;
                }
                let drain_started = *shared.drain_started.lock().unwrap();
                if let Some(started) = drain_started {
                    if shared.clock.now() > started + tcfg.drain_deadline() {
                        shared.deregister(id);
                        shared
                            .counters
                            .drain_abandoned
                            .fetch_add(1, Ordering::Relaxed);
                        let _ = stream_error(
                            &mut stream,
                            id,
                            "drain deadline exceeded",
                        );
                        return;
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Sender dropped without a send — cannot happen on
                // the dispatch path; treat as a failed stream.
                shared.deregister(id);
                let _ = stream_error(&mut stream, id, "completion lost");
                return;
            }
        }
    }
}

/// 503 for a draining server/lane: retry after the drain deadline.
fn reject_draining(
    stream: &mut TcpStream,
    tcfg: &TransportConfig,
) -> io::Result<()> {
    let secs =
        (tcfg.drain_deadline().as_secs_f64().ceil() as u64).max(1);
    http::write_response(
        stream,
        503,
        "Service Unavailable",
        "application/json",
        &[("Retry-After", secs.to_string())],
        b"{\"error\":\"draining: lane is closed to new requests\"}\n",
    )
}

fn reject(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    msg: &str,
) -> io::Result<()> {
    http::write_response(
        stream,
        status,
        reason,
        "application/json",
        &[],
        format!("{{\"error\":{}}}\n", jstr(msg)).as_bytes(),
    )
}

/// Mid-stream error (headers already went out as 200): a terminal
/// error chunk is the only honest signal left.
fn stream_error(stream: &mut TcpStream, id: u64, msg: &str) -> io::Result<()> {
    let body = format!("{{\"id\":{id},\"error\":{}}}\n", jstr(msg));
    http::write_chunk(stream, body.as_bytes())?;
    http::finish_chunked(stream)
}

/// Has the peer closed its socket?  `peek` returning 0 bytes is an
/// orderly FIN, a hard error (reset) counts too; `WouldBlock` means
/// alive-and-quiet.
///
/// Protocol decision: a FIN from the client is treated as
/// *abandonment*, even though TCP cannot distinguish a full close
/// from a half-close (`SHUT_WR`) of a client still reading.  Clients
/// of this transport must keep their socket fully open until the
/// result chunk arrives — [`client`] does — and in exchange the
/// server can free resources the moment a caller hangs up.
fn peer_closed(stream: &TcpStream) -> bool {
    let mut buf = [0u8; 1];
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let gone = match stream.peek(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => e.kind() != io::ErrorKind::WouldBlock,
    };
    let _ = stream.set_nonblocking(false);
    gone
}

/// `s` as a JSON string literal (quotes included) — the crate's one
/// escaping implementation, shared with [`Json::dump`].
fn jstr(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    write_escaped(s, &mut out);
    out
}

/// The result line streamed back to the client.  Non-finite logits
/// serialize as `null` (JSON has no NaN/inf) — the `finite` flag is
/// the per-response overflow signal.
fn outcome_json(out: &Outcome, lane_name: &str) -> String {
    use std::fmt::Write;
    let mut s = String::with_capacity(96 + out.logits.len() * 12);
    let _ = write!(
        s,
        "{{\"id\":{},\"lane\":{},\"latency_us\":{},\
         \"missed_deadline\":{},\"finite\":{},\"logits\":[",
        out.id,
        jstr(lane_name),
        out.latency.as_micros(),
        out.missed_deadline,
        out.finite,
    );
    for (i, v) in out.logits.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        if v.is_finite() {
            let _ = write!(s, "{v}");
        } else {
            s.push_str("null");
        }
    }
    s.push_str("]}\n");
    s
}

fn healthz_json(
    shared: &Shared,
    sched: &Scheduler,
    lane_names: &[String],
) -> String {
    use std::fmt::Write;
    let pool = sched.counters();
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\"status\":\"{}\",\"pending_streams\":{},\
         \"workers\":{{\"live\":{},\"busy\":{}}},\"lanes\":[",
        if shared.is_draining() { "draining" } else { "ok" },
        shared.pending_streams(),
        pool.live,
        pool.busy,
    );
    for (i, name) in lane_names.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"name\":{},\"depth\":{},\"closed\":{}}}",
            jstr(name),
            sched.lane_depth(i),
            sched.lane_is_closed(i),
        );
    }
    s.push_str("]}\n");
    s
}

/// Serialize the live engine + transport state in Prometheus text
/// exposition format: admission counters and depth per lane, the
/// streamed-completion tallies (including the per-lane non-finite /
/// overflow counter), latency summaries from the per-lane
/// [`NamedHistograms`], worker-pool gauges, and the transport
/// totals.
fn prometheus_text(
    shared: &Shared,
    sched: &Scheduler,
    lane_names: &[String],
) -> String {
    use std::fmt::Write;
    let mut s = String::with_capacity(4096);

    let gauge = |s: &mut String, name: &str, help: &str| {
        let _ = writeln!(s, "# HELP {name} {help}");
        let _ = writeln!(s, "# TYPE {name} gauge");
    };
    let counter = |s: &mut String, name: &str, help: &str| {
        let _ = writeln!(s, "# HELP {name} {help}");
        let _ = writeln!(s, "# TYPE {name} counter");
    };

    // Every label *value* below passes through `prom_escape` — lane
    // names come from config and may hold anything.
    let esc: Vec<String> =
        lane_names.iter().map(|n| crate::metrics::prom_escape(n)).collect();

    // Build + uptime identity, first so scrapers always see them.
    gauge(
        &mut s,
        "mpx_build_info",
        "build metadata as labels (value is constant 1)",
    );
    let _ = writeln!(
        s,
        "mpx_build_info{{version=\"{}\"}} 1",
        env!("CARGO_PKG_VERSION")
    );
    gauge(&mut s, "mpx_uptime_seconds", "seconds since server start");
    let _ = writeln!(s, "mpx_uptime_seconds {}", shared.clock.now().as_secs_f64());

    // Per-lane queue/admission state.
    counter(&mut s, "mpx_serve_accepted_total", "requests admitted per lane");
    for (i, name) in esc.iter().enumerate() {
        let q = sched.lane_stats(i);
        let _ = writeln!(
            s,
            "mpx_serve_accepted_total{{lane=\"{name}\"}} {}",
            q.accepted
        );
    }
    counter(&mut s, "mpx_serve_rejected_total", "admission rejections per lane");
    for (i, name) in esc.iter().enumerate() {
        let q = sched.lane_stats(i);
        let _ = writeln!(
            s,
            "mpx_serve_rejected_total{{lane=\"{name}\",reason=\"full\"}} {}",
            q.rejected - q.rejected_closed
        );
        let _ = writeln!(
            s,
            "mpx_serve_rejected_total{{lane=\"{name}\",reason=\"closed\"}} {}",
            q.rejected_closed
        );
    }
    gauge(&mut s, "mpx_serve_queue_depth", "queued requests per lane");
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_queue_depth{{lane=\"{name}\"}} {}",
            sched.lane_depth(i)
        );
    }
    gauge(&mut s, "mpx_serve_queue_peak_depth", "peak queue depth per lane");
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_queue_peak_depth{{lane=\"{name}\"}} {}",
            sched.lane_stats(i).peak_depth
        );
    }

    // Streamed-completion tallies + latency summaries.
    let (hists, tallies) = {
        let tallies = shared.tallies.lock().unwrap();
        let mut hists = NamedHistograms::new();
        for (i, t) in tallies.iter().enumerate() {
            hists.entry(&lane_names[i]).merge(&t.latency);
        }
        (hists, tallies.clone())
    };
    counter(&mut s, "mpx_serve_completed_total", "completions per lane");
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_completed_total{{lane=\"{name}\"}} {}",
            tallies[i].completed
        );
    }
    counter(
        &mut s,
        "mpx_serve_deadline_misses_total",
        "completions over their lane deadline",
    );
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_deadline_misses_total{{lane=\"{name}\"}} {}",
            tallies[i].deadline_misses
        );
    }
    counter(
        &mut s,
        "mpx_serve_nonfinite_total",
        "responses with a non-finite logit (half-precision overflow \
         accounting)",
    );
    for (i, name) in esc.iter().enumerate() {
        let _ = writeln!(
            s,
            "mpx_serve_nonfinite_total{{lane=\"{name}\"}} {}",
            tallies[i].nonfinite
        );
    }
    hists.to_prometheus("mpx_serve_latency_seconds", &mut s);

    // Worker pool.
    let pool = sched.counters();
    gauge(&mut s, "mpx_serve_workers", "worker pool state");
    let _ = writeln!(s, "mpx_serve_workers{{state=\"live\"}} {}", pool.live);
    let _ = writeln!(s, "mpx_serve_workers{{state=\"busy\"}} {}", pool.busy);
    counter(&mut s, "mpx_serve_workers_spawned_total", "workers ever spawned");
    let _ = writeln!(s, "mpx_serve_workers_spawned_total {}", pool.spawned);

    // Transport totals.
    let c = shared.counter_snapshot();
    counter(&mut s, "mpx_transport_connections_total", "accepted connections");
    let _ = writeln!(s, "mpx_transport_connections_total {}", c.connections);
    counter(&mut s, "mpx_transport_admitted_total", "requests admitted");
    let _ = writeln!(s, "mpx_transport_admitted_total {}", c.admitted);
    counter(
        &mut s,
        "mpx_transport_streamed_total",
        "completions delivered to a live client",
    );
    let _ = writeln!(s, "mpx_transport_streamed_total {}", c.streamed);
    counter(&mut s, "mpx_transport_rejected_total", "rejections by reason");
    for (reason, v) in [
        ("queue_full", c.rejected_full),
        ("draining", c.rejected_draining),
        ("unknown_lane", c.unknown_lane),
        ("malformed", c.malformed),
        ("overloaded", c.overloaded),
    ] {
        let _ = writeln!(
            s,
            "mpx_transport_rejected_total{{reason=\"{reason}\"}} {v}"
        );
    }
    counter(
        &mut s,
        "mpx_transport_disconnects_total",
        "clients gone before their result",
    );
    let _ = writeln!(s, "mpx_transport_disconnects_total {}", c.disconnects);
    counter(
        &mut s,
        "mpx_transport_drain_abandoned_total",
        "streams abandoned at the drain deadline",
    );
    let _ =
        writeln!(s, "mpx_transport_drain_abandoned_total {}", c.drain_abandoned);
    gauge(&mut s, "mpx_transport_pending_streams", "streams awaiting results");
    let _ = writeln!(
        s,
        "mpx_transport_pending_streams {}",
        shared.pending_streams()
    );
    gauge(&mut s, "mpx_transport_draining", "1 while draining");
    let _ = writeln!(
        s,
        "mpx_transport_draining {}",
        u8::from(shared.is_draining())
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_suffix_extracts_the_alias() {
        assert_eq!(lane_suffix("vit_tiny/chat"), Some("chat"));
        assert_eq!(lane_suffix("chat"), None);
        assert_eq!(lane_suffix("trailing/"), None);
        assert_eq!(lane_suffix("a/b/c"), Some("c"));
    }

    #[test]
    fn jstr_produces_quoted_escaped_literals() {
        assert_eq!(jstr("plain"), "\"plain\"");
        assert_eq!(jstr("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(jstr("\u{1}"), "\"\\u0001\"");
    }

    #[test]
    fn outcome_json_is_valid_json_even_with_nonfinite_logits() {
        let out = Outcome {
            id: 3,
            latency: Duration::from_micros(1500),
            missed_deadline: false,
            finite: false,
            logits: vec![1.0, f32::NAN, f32::INFINITY],
        };
        let line = outcome_json(&out, "vit_tiny/chat");
        let doc = Json::parse(line.trim()).unwrap();
        assert_eq!(doc.get("id").and_then(Json::as_i64), Some(3));
        assert_eq!(doc.get("finite").and_then(Json::as_bool), Some(false));
        let logits = doc.get("logits").and_then(Json::as_arr).unwrap();
        assert_eq!(logits.len(), 3);
        assert_eq!(logits[1], Json::Null);
    }
}
