//! Minimal HTTP/1.1 wire protocol — request parsing, response
//! writing, and chunked transfer encoding — over plain bytes and
//! `std::io` streams.  No external dependencies; exactly the subset
//! the transport server and
//! [`client`](crate::serve::transport::client) need:
//!
//! * an incremental [`RequestParser`] for the nonblocking reactor:
//!   feed whatever the socket produced, get complete requests out —
//!   CRLFs, header lines, and chunk-size lines may be split across
//!   reads at any byte;
//! * request line + headers + `Content-Length` or chunked request
//!   bodies;
//! * `Expect: 100-continue` (curl sends it for bodies over 1 KiB);
//! * fixed (`Content-Length`) and streamed (`Transfer-Encoding:
//!   chunked`) responses, with the `Connection` header chosen per
//!   response — HTTP/1.1 keep-alive is the default, and requests
//!   carrying `Connection: close` / `keep-alive` are honored via
//!   [`HttpRequest::wants_keep_alive`];
//! * a blocking [`read_request`] over `BufRead` for the client-side
//!   tests and tooling that still read whole messages.
//!
//! Everything is pure byte-in/byte-out and unit-tested against
//! in-memory cursors; the socket handling lives in the server/client
//! modules.

use std::fmt;
use std::io::{self, BufRead, Read, Write};

/// Parsed size guards: a request line or header may not exceed this.
pub const MAX_LINE_BYTES: usize = 16 * 1024;
/// Max headers per message.
pub const MAX_HEADERS: usize = 64;
/// Max request body.  The largest real payload is a vit_base image
/// row as JSON (~2 MiB); 8 MiB leaves slack without letting a
/// `Content-Length` header reserve silly amounts of memory.
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// Wire-level failure: either the peer spoke bad HTTP (map to `400`)
/// or the underlying stream failed (timeout, reset — just close).
#[derive(Debug)]
pub enum HttpError {
    Malformed(String),
    Io(io::Error),
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HttpError::Malformed(m) => write!(f, "malformed http: {m}"),
            HttpError::Io(e) => write!(f, "http io: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

impl From<io::Error> for HttpError {
    fn from(e: io::Error) -> HttpError {
        HttpError::Io(e)
    }
}

fn malformed(msg: impl Into<String>) -> HttpError {
    HttpError::Malformed(msg.into())
}

/// One parsed request.  Header names are lowercased; the path is
/// split from its query string.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub query: Option<String>,
    /// `(lowercase-name, value)` in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// `HTTP/1.1` (or later 1.x) — keep-alive by default.
    pub http11: bool,
}

impl HttpRequest {
    /// First value of `name` (ASCII case-insensitive lookup — names
    /// are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }

    /// Value of `key` in the query string (no percent-decoding — lane
    /// names and the keys we use are plain tokens).
    pub fn query_param(&self, key: &str) -> Option<&str> {
        let q = self.query.as_deref()?;
        q.split('&').find_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            (k == key).then_some(v)
        })
    }

    /// Should the connection stay open after this request?  HTTP/1.1
    /// defaults to keep-alive, HTTP/1.0 to close; a `Connection`
    /// header carrying `close` or `keep-alive` tokens overrides the
    /// default (last recognized token wins).
    pub fn wants_keep_alive(&self) -> bool {
        let mut keep = self.http11;
        if let Some(v) = self.header("connection") {
            for token in v.split(',') {
                let token = token.trim();
                if token.eq_ignore_ascii_case("close") {
                    keep = false;
                } else if token.eq_ignore_ascii_case("keep-alive") {
                    keep = true;
                }
            }
        }
        keep
    }
}

/// First value of `name` in a `(lowercase-name, value)` header list.
pub fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    let name = name.to_ascii_lowercase();
    headers
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, v)| v.as_str())
}

/// Split a request line into `(method, path, query, http11)`.
fn parse_request_line(
    line: &str,
) -> Result<(String, String, Option<String>, bool), HttpError> {
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| malformed("empty request line"))?;
    let target = parts.next().ok_or_else(|| malformed("missing path"))?;
    let version = parts.next().ok_or_else(|| malformed("missing version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(malformed(format!("unsupported version {version:?}")));
    }
    let http11 = version != "HTTP/1.0";
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    Ok((method.to_string(), path, query, http11))
}

/// Split one `Name: value` header line, lowercasing the name.
fn parse_header_line(line: &str) -> Result<(String, String), HttpError> {
    let (name, value) = line
        .split_once(':')
        .ok_or_else(|| malformed(format!("bad header line {line:?}")))?;
    Ok((name.trim().to_ascii_lowercase(), value.trim().to_string()))
}

/// Read one CRLF (or bare-LF) terminated line, without the
/// terminator.  `Ok(None)` is clean EOF before any byte.
fn read_line(r: &mut impl BufRead) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::new();
    let mut limited = r.take(MAX_LINE_BYTES as u64 + 1);
    let n = limited.read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    if buf.len() > MAX_LINE_BYTES {
        return Err(malformed("header line too long"));
    }
    if buf.last() == Some(&b'\n') {
        buf.pop();
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
    } else {
        // EOF mid-line.
        return Err(malformed("truncated line"));
    }
    String::from_utf8(buf).map(Some).map_err(|_| malformed("non-utf8 line"))
}

/// Read one full request from `r` (blocking).  `w` is the same
/// connection's write half, used only to acknowledge `Expect:
/// 100-continue` before the body is read.  `Ok(None)` means the peer
/// closed without sending anything (a clean no-request connection).
/// Chunked request bodies are rejected here; the incremental
/// [`RequestParser`] the server runs accepts them.
pub fn read_request(
    r: &mut impl BufRead,
    w: &mut impl Write,
) -> Result<Option<HttpRequest>, HttpError> {
    let Some(line) = read_line(r)? else {
        return Ok(None);
    };
    let (method, path, query, http11) = parse_request_line(&line)?;

    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?.ok_or_else(|| malformed("eof in headers"))?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(malformed("too many headers"));
        }
        headers.push(parse_header_line(&line)?);
    }

    if header(&headers, "transfer-encoding")
        .is_some_and(|v| v.to_ascii_lowercase().contains("chunked"))
    {
        return Err(malformed("chunked request bodies are not supported"));
    }
    let body = match header(&headers, "content-length") {
        Some(v) => {
            let len: usize = v
                .trim()
                .parse()
                .map_err(|_| malformed(format!("bad content-length {v:?}")))?;
            if len > MAX_BODY_BYTES {
                return Err(malformed(format!("body of {len} bytes too large")));
            }
            if header(&headers, "expect")
                .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
            {
                w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
                w.flush()?;
            }
            read_exactly(r, len)?
        }
        None => Vec::new(),
    };

    Ok(Some(HttpRequest { method, path, query, headers, body, http11 }))
}

/// A complete request head, waiting for (or already owning) its body.
#[derive(Debug)]
struct Head {
    method: String,
    path: String,
    query: Option<String>,
    headers: Vec<(String, String)>,
    http11: bool,
}

#[derive(Debug)]
enum ParseState {
    /// Accumulating request + header lines; `lines[0]` is the request
    /// line once it has arrived.
    Lines { lines: Vec<String> },
    /// Reading a `Content-Length` body.
    Body { head: Head, remaining: usize, body: Vec<u8> },
    /// Expecting a chunk-size line.
    ChunkSize { head: Head, body: Vec<u8> },
    /// Copying chunk payload bytes.
    ChunkData { head: Head, remaining: usize, body: Vec<u8> },
    /// Expecting the CRLF that terminates a chunk's payload.
    ChunkCrlf { head: Head, body: Vec<u8> },
    /// Consuming (and discarding) trailer lines after the 0-chunk.
    Trailers { head: Head, body: Vec<u8> },
    /// A previous feed produced a protocol error; the connection is
    /// done.
    Failed,
}

/// Incremental HTTP/1.1 request parser for nonblocking sockets.
///
/// [`feed`](RequestParser::feed) whatever bytes the socket produced
/// — any split point is fine, including mid-CRLF and mid
/// chunk-size-line — then drain complete messages with
/// [`next_request`](RequestParser::next_request).  Pipelined
/// requests buffered in one read come out one at a time, in order.
///
/// Unlike the blocking [`read_request`], chunked *request* bodies
/// are accepted: the reactor never blocks on a body, so there is no
/// reason to reject them.  All the same guards apply
/// ([`MAX_LINE_BYTES`], [`MAX_HEADERS`], [`MAX_BODY_BYTES`]).
#[derive(Debug)]
pub struct RequestParser {
    buf: Vec<u8>,
    pos: usize,
    state: ParseState,
    interim: Vec<u8>,
}

impl Default for RequestParser {
    fn default() -> RequestParser {
        RequestParser::new()
    }
}

impl RequestParser {
    pub fn new() -> RequestParser {
        RequestParser {
            buf: Vec::new(),
            pos: 0,
            state: ParseState::Lines { lines: Vec::new() },
            interim: Vec::new(),
        }
    }

    /// Append socket bytes.  Call [`next_request`] afterwards (in a
    /// loop — one read may complete several pipelined requests).
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.pos > 0 && (self.pos == self.buf.len() || self.pos >= 64 * 1024)
        {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// A request is partially buffered: the whole-request deadline
    /// clock should be running.  False only when the parser sits
    /// exactly on a message boundary with no unconsumed bytes.
    pub fn mid_request(&self) -> bool {
        match &self.state {
            ParseState::Lines { lines } => {
                !lines.is_empty() || self.pos < self.buf.len()
            }
            ParseState::Failed => false,
            _ => true,
        }
    }

    /// Interim response bytes (`100 Continue`) the server should
    /// write before the peer sends its body, if any were queued by
    /// the last `next_request` round.
    pub fn take_interim(&mut self) -> Option<Vec<u8>> {
        if self.interim.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.interim))
        }
    }

    /// Pop the next complete request, or `Ok(None)` if more bytes are
    /// needed.  A `Malformed` error is terminal for the connection —
    /// resynchronizing an HTTP/1.1 byte stream after a framing error
    /// is not possible.
    pub fn next_request(&mut self) -> Result<Option<HttpRequest>, HttpError> {
        loop {
            let state =
                std::mem::replace(&mut self.state, ParseState::Failed);
            match state {
                ParseState::Failed => {
                    return Err(malformed("parser already failed"));
                }
                ParseState::Lines { mut lines } => {
                    let Some(line) = self.take_line()? else {
                        self.state = ParseState::Lines { lines };
                        return Ok(None);
                    };
                    if !line.is_empty() || lines.is_empty() {
                        // Request line or header line; the head is
                        // validated once the blank line arrives.
                        if lines.len() > MAX_HEADERS {
                            return Err(malformed("too many headers"));
                        }
                        lines.push(line);
                        self.state = ParseState::Lines { lines };
                        continue;
                    }
                    self.state = self.finish_head(&lines)?;
                }
                ParseState::Body { head, mut remaining, mut body } => {
                    let take = (self.buf.len() - self.pos).min(remaining);
                    body.extend_from_slice(
                        &self.buf[self.pos..self.pos + take],
                    );
                    self.pos += take;
                    remaining -= take;
                    if remaining > 0 {
                        self.state = ParseState::Body { head, remaining, body };
                        return Ok(None);
                    }
                    return Ok(Some(self.complete(head, body)));
                }
                ParseState::ChunkSize { head, body } => {
                    let Some(line) = self.take_line()? else {
                        self.state = ParseState::ChunkSize { head, body };
                        return Ok(None);
                    };
                    let size_str = line.split(';').next().unwrap_or("").trim();
                    let size = usize::from_str_radix(size_str, 16).map_err(
                        |_| malformed(format!("bad chunk size {line:?}")),
                    )?;
                    if body.len().saturating_add(size) > MAX_BODY_BYTES {
                        return Err(malformed(format!(
                            "chunked body over {MAX_BODY_BYTES} bytes"
                        )));
                    }
                    self.state = if size == 0 {
                        ParseState::Trailers { head, body }
                    } else {
                        ParseState::ChunkData { head, remaining: size, body }
                    };
                }
                ParseState::ChunkData { head, mut remaining, mut body } => {
                    let take = (self.buf.len() - self.pos).min(remaining);
                    body.extend_from_slice(
                        &self.buf[self.pos..self.pos + take],
                    );
                    self.pos += take;
                    remaining -= take;
                    if remaining > 0 {
                        self.state =
                            ParseState::ChunkData { head, remaining, body };
                        return Ok(None);
                    }
                    self.state = ParseState::ChunkCrlf { head, body };
                }
                ParseState::ChunkCrlf { head, body } => {
                    if self.buf.len() - self.pos < 2 {
                        self.state = ParseState::ChunkCrlf { head, body };
                        return Ok(None);
                    }
                    let crlf = &self.buf[self.pos..self.pos + 2];
                    if crlf != b"\r\n" {
                        return Err(malformed("chunk not CRLF-terminated"));
                    }
                    self.pos += 2;
                    self.state = ParseState::ChunkSize { head, body };
                }
                ParseState::Trailers { head, body } => {
                    let Some(line) = self.take_line()? else {
                        self.state = ParseState::Trailers { head, body };
                        return Ok(None);
                    };
                    if line.is_empty() {
                        return Ok(Some(self.complete(head, body)));
                    }
                    self.state = ParseState::Trailers { head, body };
                }
            }
        }
    }

    /// Take one buffered line if its terminator has arrived.
    fn take_line(&mut self) -> Result<Option<String>, HttpError> {
        let avail = &self.buf[self.pos..];
        let Some(nl) = avail.iter().position(|&b| b == b'\n') else {
            if avail.len() > MAX_LINE_BYTES {
                return Err(malformed("header line too long"));
            }
            return Ok(None);
        };
        if nl > MAX_LINE_BYTES {
            return Err(malformed("header line too long"));
        }
        let mut end = nl;
        if end > 0 && avail[end - 1] == b'\r' {
            end -= 1;
        }
        let line = std::str::from_utf8(&avail[..end])
            .map_err(|_| malformed("non-utf8 line"))?
            .to_string();
        self.pos += nl + 1;
        Ok(Some(line))
    }

    /// Blank line seen: parse the accumulated head lines and pick the
    /// body-reading state.
    fn finish_head(&mut self, lines: &[String]) -> Result<ParseState, HttpError> {
        let first = lines.first().map(String::as_str).unwrap_or("");
        let (method, path, query, http11) = parse_request_line(first)?;
        let mut headers = Vec::with_capacity(lines.len().saturating_sub(1));
        for line in &lines[1..] {
            headers.push(parse_header_line(line)?);
        }
        let head = Head { method, path, query, headers, http11 };

        let expects_continue = header(&head.headers, "expect")
            .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"));
        if header(&head.headers, "transfer-encoding")
            .is_some_and(|v| v.to_ascii_lowercase().contains("chunked"))
        {
            if expects_continue {
                self.interim.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
            }
            return Ok(ParseState::ChunkSize { head, body: Vec::new() });
        }
        match header(&head.headers, "content-length") {
            Some(v) => {
                let len: usize = v.trim().parse().map_err(|_| {
                    malformed(format!("bad content-length {v:?}"))
                })?;
                if len > MAX_BODY_BYTES {
                    return Err(malformed(format!(
                        "body of {len} bytes too large"
                    )));
                }
                if len == 0 {
                    return Ok(ParseState::Body {
                        head,
                        remaining: 0,
                        body: Vec::new(),
                    });
                }
                if expects_continue {
                    self.interim
                        .extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
                }
                Ok(ParseState::Body { head, remaining: len, body: Vec::new() })
            }
            None => {
                Ok(ParseState::Body { head, remaining: 0, body: Vec::new() })
            }
        }
    }

    fn complete(&mut self, head: Head, body: Vec<u8>) -> HttpRequest {
        self.state = ParseState::Lines { lines: Vec::new() };
        HttpRequest {
            method: head.method,
            path: head.path,
            query: head.query,
            headers: head.headers,
            body,
            http11: head.http11,
        }
    }
}

fn write_head(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    keep_alive: bool,
    extra: &[(&str, String)],
) -> io::Result<()> {
    write!(w, "HTTP/1.1 {status} {reason}\r\n")?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(w, "Connection: {conn}\r\n")?;
    for (name, value) in extra {
        write!(w, "{name}: {value}\r\n")?;
    }
    Ok(())
}

/// Write a complete fixed-length response and flush.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    keep_alive: bool,
    extra: &[(&str, String)],
    body: &[u8],
) -> io::Result<()> {
    write_head(w, status, reason, content_type, keep_alive, extra)?;
    write!(w, "Content-Length: {}\r\n\r\n", body.len())?;
    w.write_all(body)?;
    w.flush()
}

/// Start a chunked streaming response (headers only) and flush, so
/// the client learns its admission status before the first result
/// chunk exists.
pub fn start_chunked(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    keep_alive: bool,
    extra: &[(&str, String)],
) -> io::Result<()> {
    write_head(w, status, reason, content_type, keep_alive, extra)?;
    write!(w, "Transfer-Encoding: chunked\r\n\r\n")?;
    w.flush()
}

/// Write one chunk and flush.  Empty data is skipped (a zero-size
/// chunk would terminate the stream).
pub fn write_chunk(w: &mut impl Write, data: &[u8]) -> io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked stream.
pub fn finish_chunked(w: &mut impl Write) -> io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

/// A parsed response status line + headers (client side).  The body
/// is read separately ([`read_chunk`] / [`read_sized_body`]) so
/// callers can stream.
#[derive(Debug)]
pub struct ResponseHead {
    pub status: u16,
    pub reason: String,
    pub headers: Vec<(String, String)>,
}

impl ResponseHead {
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }

    pub fn is_chunked(&self) -> bool {
        self.header("transfer-encoding")
            .is_some_and(|v| v.to_ascii_lowercase().contains("chunked"))
    }

    /// Did the server promise to keep the connection open?
    pub fn is_keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) => !v.eq_ignore_ascii_case("close"),
            None => true,
        }
    }
}

/// Read a response status line + headers.  Interim `100 Continue`
/// responses are consumed transparently.
pub fn read_response_head(
    r: &mut impl BufRead,
) -> Result<ResponseHead, HttpError> {
    loop {
        let line = read_line(r)?.ok_or_else(|| malformed("eof at status"))?;
        let mut parts = line.splitn(3, ' ');
        let version =
            parts.next().ok_or_else(|| malformed("empty status line"))?;
        if !version.starts_with("HTTP/1.") {
            return Err(malformed(format!("bad status line {line:?}")));
        }
        let status: u16 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| malformed(format!("bad status in {line:?}")))?;
        let reason = parts.next().unwrap_or("").to_string();

        let mut headers = Vec::new();
        loop {
            let line =
                read_line(r)?.ok_or_else(|| malformed("eof in headers"))?;
            if line.is_empty() {
                break;
            }
            if headers.len() >= MAX_HEADERS {
                return Err(malformed("too many headers"));
            }
            headers.push(parse_header_line(&line)?);
        }
        if status == 100 {
            continue;
        }
        return Ok(ResponseHead { status, reason, headers });
    }
}

/// Read one chunk of a chunked response body; `Ok(None)` is the
/// terminal chunk (trailers, if any, are consumed and discarded).
pub fn read_chunk(r: &mut impl BufRead) -> Result<Option<Vec<u8>>, HttpError> {
    let line = read_line(r)?.ok_or_else(|| malformed("eof at chunk size"))?;
    let size_str = line.split(';').next().unwrap_or("").trim();
    let size = usize::from_str_radix(size_str, 16)
        .map_err(|_| malformed(format!("bad chunk size {line:?}")))?;
    if size > MAX_BODY_BYTES {
        return Err(malformed(format!("chunk of {size} bytes too large")));
    }
    if size == 0 {
        // Trailers until the blank line.
        loop {
            let line =
                read_line(r)?.ok_or_else(|| malformed("eof in trailers"))?;
            if line.is_empty() {
                break;
            }
        }
        return Ok(None);
    }
    let data = read_exactly(r, size)?;
    let mut crlf = [0u8; 2];
    r.read_exact(&mut crlf)?;
    if &crlf != b"\r\n" {
        return Err(malformed("chunk not CRLF-terminated"));
    }
    Ok(Some(data))
}

/// Read exactly `len` body bytes, growing the buffer chunk by chunk
/// — memory is committed only as bytes actually arrive, so a
/// `Content-Length` header alone cannot reserve `len` bytes.
fn read_exactly(
    r: &mut impl BufRead,
    len: usize,
) -> Result<Vec<u8>, HttpError> {
    const CHUNK: usize = 64 * 1024;
    let mut body = Vec::with_capacity(len.min(CHUNK));
    let mut buf = [0u8; CHUNK];
    let mut remaining = len;
    while remaining > 0 {
        let take = remaining.min(CHUNK);
        r.read_exact(&mut buf[..take])?;
        body.extend_from_slice(&buf[..take]);
        remaining -= take;
    }
    Ok(body)
}

/// Read a `Content-Length` body.
pub fn read_sized_body(
    r: &mut impl BufRead,
    len: usize,
) -> Result<Vec<u8>, HttpError> {
    if len > MAX_BODY_BYTES {
        return Err(malformed(format!("body of {len} bytes too large")));
    }
    read_exactly(r, len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<HttpRequest>, HttpError> {
        let mut r = Cursor::new(raw.as_bytes().to_vec());
        let mut sink = Vec::new();
        read_request(&mut r, &mut sink)
    }

    #[test]
    fn parses_a_post_with_body() {
        let req = parse(
            "POST /v1/infer?lane=chat HTTP/1.1\r\nHost: x\r\nContent-Type: \
             application/json\r\nContent-Length: 4\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/infer");
        assert_eq!(req.query_param("lane"), Some("chat"));
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.header("Content-Type"), Some("application/json"));
        assert_eq!(req.body, b"abcd");
        assert!(req.http11);
        assert!(req.wants_keep_alive());
    }

    #[test]
    fn clean_eof_is_none_and_garbage_is_malformed() {
        assert!(parse("").unwrap().is_none());
        assert!(matches!(
            parse("not http at all\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        assert!(matches!(
            parse("GET / SPDY/3\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        // Truncated body: io error, not a hang.
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"),
            Err(HttpError::Io(_))
        ));
        // Chunked request bodies are rejected by the blocking reader.
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
    }

    #[test]
    fn connection_header_controls_keep_alive() {
        let keep = |raw: &str| parse(raw).unwrap().unwrap().wants_keep_alive();
        assert!(keep("GET / HTTP/1.1\r\n\r\n"), "1.1 defaults on");
        assert!(!keep("GET / HTTP/1.0\r\n\r\n"), "1.0 defaults off");
        assert!(!keep("GET / HTTP/1.1\r\nConnection: close\r\n\r\n"));
        assert!(keep("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n"));
        assert!(!keep("GET / HTTP/1.1\r\nConnection: Close\r\n\r\n"));
        assert!(!keep(
            "GET / HTTP/1.1\r\nConnection: keep-alive, close\r\n\r\n"
        ));
    }

    #[test]
    fn expect_100_continue_is_acknowledged() {
        let raw = "POST / HTTP/1.1\r\nExpect: 100-continue\r\n\
                   Content-Length: 2\r\n\r\nok";
        let mut r = Cursor::new(raw.as_bytes().to_vec());
        let mut sink = Vec::new();
        let req = read_request(&mut r, &mut sink).unwrap().unwrap();
        assert_eq!(req.body, b"ok");
        assert_eq!(sink, b"HTTP/1.1 100 Continue\r\n\r\n");
    }

    #[test]
    fn incremental_parser_survives_any_split_point() {
        let raw = "POST /v1/infer HTTP/1.1\r\nHost: x\r\n\
                   Content-Length: 4\r\n\r\nabcd";
        // Feed byte by byte: no request until the very last byte.
        let mut p = RequestParser::new();
        for (i, b) in raw.as_bytes().iter().enumerate() {
            p.feed(&[*b]);
            let got = p.next_request().unwrap();
            if i + 1 < raw.len() {
                assert!(got.is_none(), "complete after byte {i}?");
                assert!(p.mid_request());
            } else {
                let req = got.unwrap();
                assert_eq!(req.path, "/v1/infer");
                assert_eq!(req.body, b"abcd");
            }
        }
        assert!(!p.mid_request(), "boundary after a full message");
    }

    #[test]
    fn incremental_parser_handles_split_chunk_size_lines() {
        let raw = "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                   4\r\nwiki\r\n5\r\npedia\r\n0\r\n\r\n";
        // Split inside the head, inside a chunk-size line, inside a
        // chunk payload, and inside the terminating CRLF.
        for split in [10, 44, 47, 50, 56, raw.len() - 1] {
            let mut p = RequestParser::new();
            p.feed(&raw.as_bytes()[..split]);
            assert!(
                p.next_request().unwrap().is_none(),
                "complete at split {split}?"
            );
            p.feed(&raw.as_bytes()[split..]);
            let req = p.next_request().unwrap().unwrap();
            assert_eq!(req.body, b"wikipedia", "split {split}");
        }
    }

    #[test]
    fn incremental_parser_yields_pipelined_requests_in_order() {
        let raw = "POST /a HTTP/1.1\r\nContent-Length: 1\r\n\r\nx\
                   GET /b HTTP/1.1\r\n\r\n\
                   POST /c HTTP/1.1\r\nConnection: close\r\n\
                   Content-Length: 2\r\n\r\nyz";
        let mut p = RequestParser::new();
        p.feed(raw.as_bytes());
        let a = p.next_request().unwrap().unwrap();
        let b = p.next_request().unwrap().unwrap();
        let c = p.next_request().unwrap().unwrap();
        assert_eq!((a.path.as_str(), a.body.as_slice()), ("/a", &b"x"[..]));
        assert_eq!(b.path, "/b");
        assert!(b.body.is_empty());
        assert_eq!((c.path.as_str(), c.body.as_slice()), ("/c", &b"yz"[..]));
        assert!(!c.wants_keep_alive());
        assert!(p.next_request().unwrap().is_none());
        assert!(!p.mid_request());
    }

    #[test]
    fn incremental_parser_queues_the_100_continue_interim() {
        let mut p = RequestParser::new();
        p.feed(
            b"POST / HTTP/1.1\r\nExpect: 100-continue\r\n\
              Content-Length: 2\r\n\r\n",
        );
        assert!(p.next_request().unwrap().is_none());
        assert_eq!(
            p.take_interim().as_deref(),
            Some(&b"HTTP/1.1 100 Continue\r\n\r\n"[..])
        );
        assert!(p.take_interim().is_none(), "interim is taken once");
        p.feed(b"ok");
        assert_eq!(p.next_request().unwrap().unwrap().body, b"ok");
    }

    #[test]
    fn incremental_parser_rejects_garbage_terminally() {
        let mut p = RequestParser::new();
        p.feed(b"not http at all\r\n\r\n");
        assert!(matches!(p.next_request(), Err(HttpError::Malformed(_))));
        assert!(matches!(p.next_request(), Err(HttpError::Malformed(_))));
    }

    #[test]
    fn response_roundtrip_fixed() {
        let mut out = Vec::new();
        write_response(
            &mut out,
            404,
            "Not Found",
            "application/json",
            false,
            &[("Retry-After", "1".to_string())],
            b"{\"error\":\"x\"}",
        )
        .unwrap();
        let mut r = Cursor::new(out);
        let head = read_response_head(&mut r).unwrap();
        assert_eq!(head.status, 404);
        assert_eq!(head.header("retry-after"), Some("1"));
        assert_eq!(head.header("connection"), Some("close"));
        assert!(!head.is_keep_alive());
        let len: usize =
            head.header("content-length").unwrap().parse().unwrap();
        let body = read_sized_body(&mut r, len).unwrap();
        assert_eq!(body, b"{\"error\":\"x\"}");
    }

    #[test]
    fn response_roundtrip_chunked() {
        let mut out = Vec::new();
        start_chunked(&mut out, 200, "OK", "application/x-ndjson", true, &[])
            .unwrap();
        write_chunk(&mut out, b"first\n").unwrap();
        write_chunk(&mut out, b"").unwrap(); // skipped, not terminal
        write_chunk(&mut out, b"second\n").unwrap();
        finish_chunked(&mut out).unwrap();

        let mut r = Cursor::new(out);
        let head = read_response_head(&mut r).unwrap();
        assert_eq!(head.status, 200);
        assert!(head.is_chunked());
        assert_eq!(head.header("connection"), Some("keep-alive"));
        assert!(head.is_keep_alive());
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"first\n");
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"second\n");
        assert!(read_chunk(&mut r).unwrap().is_none());
    }

    #[test]
    fn interim_100_is_skipped_by_the_client() {
        let mut out = Vec::new();
        out.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
        write_response(&mut out, 200, "OK", "text/plain", false, &[], b"hi")
            .unwrap();
        let mut r = Cursor::new(out);
        let head = read_response_head(&mut r).unwrap();
        assert_eq!(head.status, 200);
    }
}
