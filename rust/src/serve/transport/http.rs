//! Minimal HTTP/1.1 wire protocol — request parsing, response
//! writing, and chunked transfer encoding — over plain `std::io`
//! streams.  No external dependencies; exactly the subset the
//! transport server and [`client`](crate::serve::transport::client)
//! need:
//!
//! * request line + headers + `Content-Length` bodies (chunked
//!   *request* bodies are rejected — inference payloads are always
//!   sized up front);
//! * `Expect: 100-continue` (curl sends it for bodies over 1 KiB);
//! * fixed (`Content-Length`) and streamed (`Transfer-Encoding:
//!   chunked`) responses, one request per connection
//!   (`Connection: close`).
//!
//! Everything is pure byte-in/byte-out and unit-tested against
//! in-memory cursors; the socket handling lives in the server/client
//! modules.

use std::fmt;
use std::io::{self, BufRead, Read, Write};

/// Parsed size guards: a request line or header may not exceed this.
pub const MAX_LINE_BYTES: usize = 16 * 1024;
/// Max headers per message.
pub const MAX_HEADERS: usize = 64;
/// Max request body.  The largest real payload is a vit_base image
/// row as JSON (~2 MiB); 8 MiB leaves slack without letting a
/// `Content-Length` header reserve silly amounts of memory.
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// Wire-level failure: either the peer spoke bad HTTP (map to `400`)
/// or the underlying stream failed (timeout, reset — just close).
#[derive(Debug)]
pub enum HttpError {
    Malformed(String),
    Io(io::Error),
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HttpError::Malformed(m) => write!(f, "malformed http: {m}"),
            HttpError::Io(e) => write!(f, "http io: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

impl From<io::Error> for HttpError {
    fn from(e: io::Error) -> HttpError {
        HttpError::Io(e)
    }
}

fn malformed(msg: impl Into<String>) -> HttpError {
    HttpError::Malformed(msg.into())
}

/// One parsed request.  Header names are lowercased; the path is
/// split from its query string.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub query: Option<String>,
    /// `(lowercase-name, value)` in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First value of `name` (ASCII case-insensitive lookup — names
    /// are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }

    /// Value of `key` in the query string (no percent-decoding — lane
    /// names and the keys we use are plain tokens).
    pub fn query_param(&self, key: &str) -> Option<&str> {
        let q = self.query.as_deref()?;
        q.split('&').find_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            (k == key).then_some(v)
        })
    }
}

/// First value of `name` in a `(lowercase-name, value)` header list.
pub fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    let name = name.to_ascii_lowercase();
    headers
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, v)| v.as_str())
}

/// Read one CRLF (or bare-LF) terminated line, without the
/// terminator.  `Ok(None)` is clean EOF before any byte.
fn read_line(r: &mut impl BufRead) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::new();
    let mut limited = r.take(MAX_LINE_BYTES as u64 + 1);
    let n = limited.read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    if buf.len() > MAX_LINE_BYTES {
        return Err(malformed("header line too long"));
    }
    if buf.last() == Some(&b'\n') {
        buf.pop();
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
    } else {
        // EOF mid-line.
        return Err(malformed("truncated line"));
    }
    String::from_utf8(buf).map(Some).map_err(|_| malformed("non-utf8 line"))
}

/// Read one full request from `r`.  `w` is the same connection's
/// write half, used only to acknowledge `Expect: 100-continue` before
/// the body is read.  `Ok(None)` means the peer closed without
/// sending anything (a clean no-request connection).
pub fn read_request(
    r: &mut impl BufRead,
    w: &mut impl Write,
) -> Result<Option<HttpRequest>, HttpError> {
    let Some(line) = read_line(r)? else {
        return Ok(None);
    };
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| malformed("empty request line"))?;
    let target = parts.next().ok_or_else(|| malformed("missing path"))?;
    let version = parts.next().ok_or_else(|| malformed("missing version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(malformed(format!("unsupported version {version:?}")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?.ok_or_else(|| malformed("eof in headers"))?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(malformed("too many headers"));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| malformed(format!("bad header line {line:?}")))?;
        headers.push((
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }

    if header(&headers, "transfer-encoding")
        .is_some_and(|v| v.to_ascii_lowercase().contains("chunked"))
    {
        return Err(malformed("chunked request bodies are not supported"));
    }
    let body = match header(&headers, "content-length") {
        Some(v) => {
            let len: usize = v
                .trim()
                .parse()
                .map_err(|_| malformed(format!("bad content-length {v:?}")))?;
            if len > MAX_BODY_BYTES {
                return Err(malformed(format!("body of {len} bytes too large")));
            }
            if header(&headers, "expect")
                .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
            {
                w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
                w.flush()?;
            }
            read_exactly(r, len)?
        }
        None => Vec::new(),
    };

    Ok(Some(HttpRequest {
        method: method.to_string(),
        path,
        query,
        headers,
        body,
    }))
}

fn write_head(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, String)],
) -> io::Result<()> {
    write!(w, "HTTP/1.1 {status} {reason}\r\n")?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    write!(w, "Connection: close\r\n")?;
    for (name, value) in extra {
        write!(w, "{name}: {value}\r\n")?;
    }
    Ok(())
}

/// Write a complete fixed-length response and flush.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, String)],
    body: &[u8],
) -> io::Result<()> {
    write_head(w, status, reason, content_type, extra)?;
    write!(w, "Content-Length: {}\r\n\r\n", body.len())?;
    w.write_all(body)?;
    w.flush()
}

/// Start a chunked streaming response (headers only) and flush, so
/// the client learns its admission status before the first result
/// chunk exists.
pub fn start_chunked(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, String)],
) -> io::Result<()> {
    write_head(w, status, reason, content_type, extra)?;
    write!(w, "Transfer-Encoding: chunked\r\n\r\n")?;
    w.flush()
}

/// Write one chunk and flush.  Empty data is skipped (a zero-size
/// chunk would terminate the stream).
pub fn write_chunk(w: &mut impl Write, data: &[u8]) -> io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked stream.
pub fn finish_chunked(w: &mut impl Write) -> io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

/// A parsed response status line + headers (client side).  The body
/// is read separately ([`read_chunk`] / [`read_sized_body`]) so
/// callers can stream.
#[derive(Debug)]
pub struct ResponseHead {
    pub status: u16,
    pub reason: String,
    pub headers: Vec<(String, String)>,
}

impl ResponseHead {
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }

    pub fn is_chunked(&self) -> bool {
        self.header("transfer-encoding")
            .is_some_and(|v| v.to_ascii_lowercase().contains("chunked"))
    }
}

/// Read a response status line + headers.  Interim `100 Continue`
/// responses are consumed transparently.
pub fn read_response_head(
    r: &mut impl BufRead,
) -> Result<ResponseHead, HttpError> {
    loop {
        let line = read_line(r)?.ok_or_else(|| malformed("eof at status"))?;
        let mut parts = line.splitn(3, ' ');
        let version =
            parts.next().ok_or_else(|| malformed("empty status line"))?;
        if !version.starts_with("HTTP/1.") {
            return Err(malformed(format!("bad status line {line:?}")));
        }
        let status: u16 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| malformed(format!("bad status in {line:?}")))?;
        let reason = parts.next().unwrap_or("").to_string();

        let mut headers = Vec::new();
        loop {
            let line =
                read_line(r)?.ok_or_else(|| malformed("eof in headers"))?;
            if line.is_empty() {
                break;
            }
            if headers.len() >= MAX_HEADERS {
                return Err(malformed("too many headers"));
            }
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| malformed(format!("bad header {line:?}")))?;
            headers.push((
                name.trim().to_ascii_lowercase(),
                value.trim().to_string(),
            ));
        }
        if status == 100 {
            continue;
        }
        return Ok(ResponseHead { status, reason, headers });
    }
}

/// Read one chunk of a chunked response body; `Ok(None)` is the
/// terminal chunk (trailers, if any, are consumed and discarded).
pub fn read_chunk(r: &mut impl BufRead) -> Result<Option<Vec<u8>>, HttpError> {
    let line = read_line(r)?.ok_or_else(|| malformed("eof at chunk size"))?;
    let size_str = line.split(';').next().unwrap_or("").trim();
    let size = usize::from_str_radix(size_str, 16)
        .map_err(|_| malformed(format!("bad chunk size {line:?}")))?;
    if size > MAX_BODY_BYTES {
        return Err(malformed(format!("chunk of {size} bytes too large")));
    }
    if size == 0 {
        // Trailers until the blank line.
        loop {
            let line =
                read_line(r)?.ok_or_else(|| malformed("eof in trailers"))?;
            if line.is_empty() {
                break;
            }
        }
        return Ok(None);
    }
    let data = read_exactly(r, size)?;
    let mut crlf = [0u8; 2];
    r.read_exact(&mut crlf)?;
    if &crlf != b"\r\n" {
        return Err(malformed("chunk not CRLF-terminated"));
    }
    Ok(Some(data))
}

/// Read exactly `len` body bytes, growing the buffer chunk by chunk
/// — memory is committed only as bytes actually arrive, so a
/// `Content-Length` header alone cannot reserve `len` bytes.
fn read_exactly(
    r: &mut impl BufRead,
    len: usize,
) -> Result<Vec<u8>, HttpError> {
    const CHUNK: usize = 64 * 1024;
    let mut body = Vec::with_capacity(len.min(CHUNK));
    let mut buf = [0u8; CHUNK];
    let mut remaining = len;
    while remaining > 0 {
        let take = remaining.min(CHUNK);
        r.read_exact(&mut buf[..take])?;
        body.extend_from_slice(&buf[..take]);
        remaining -= take;
    }
    Ok(body)
}

/// Read a `Content-Length` body.
pub fn read_sized_body(
    r: &mut impl BufRead,
    len: usize,
) -> Result<Vec<u8>, HttpError> {
    if len > MAX_BODY_BYTES {
        return Err(malformed(format!("body of {len} bytes too large")));
    }
    read_exactly(r, len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<HttpRequest>, HttpError> {
        let mut r = Cursor::new(raw.as_bytes().to_vec());
        let mut sink = Vec::new();
        read_request(&mut r, &mut sink)
    }

    #[test]
    fn parses_a_post_with_body() {
        let req = parse(
            "POST /v1/infer?lane=chat HTTP/1.1\r\nHost: x\r\nContent-Type: \
             application/json\r\nContent-Length: 4\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/infer");
        assert_eq!(req.query_param("lane"), Some("chat"));
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.header("Content-Type"), Some("application/json"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn clean_eof_is_none_and_garbage_is_malformed() {
        assert!(parse("").unwrap().is_none());
        assert!(matches!(
            parse("not http at all\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        assert!(matches!(
            parse("GET / SPDY/3\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        // Truncated body: io error, not a hang.
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"),
            Err(HttpError::Io(_))
        ));
        // Chunked request bodies are rejected up front.
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
    }

    #[test]
    fn expect_100_continue_is_acknowledged() {
        let raw = "POST / HTTP/1.1\r\nExpect: 100-continue\r\n\
                   Content-Length: 2\r\n\r\nok";
        let mut r = Cursor::new(raw.as_bytes().to_vec());
        let mut sink = Vec::new();
        let req = read_request(&mut r, &mut sink).unwrap().unwrap();
        assert_eq!(req.body, b"ok");
        assert_eq!(sink, b"HTTP/1.1 100 Continue\r\n\r\n");
    }

    #[test]
    fn response_roundtrip_fixed() {
        let mut out = Vec::new();
        write_response(
            &mut out,
            404,
            "Not Found",
            "application/json",
            &[("Retry-After", "1".to_string())],
            b"{\"error\":\"x\"}",
        )
        .unwrap();
        let mut r = Cursor::new(out);
        let head = read_response_head(&mut r).unwrap();
        assert_eq!(head.status, 404);
        assert_eq!(head.header("retry-after"), Some("1"));
        let len: usize =
            head.header("content-length").unwrap().parse().unwrap();
        let body = read_sized_body(&mut r, len).unwrap();
        assert_eq!(body, b"{\"error\":\"x\"}");
    }

    #[test]
    fn response_roundtrip_chunked() {
        let mut out = Vec::new();
        start_chunked(&mut out, 200, "OK", "application/x-ndjson", &[])
            .unwrap();
        write_chunk(&mut out, b"first\n").unwrap();
        write_chunk(&mut out, b"").unwrap(); // skipped, not terminal
        write_chunk(&mut out, b"second\n").unwrap();
        finish_chunked(&mut out).unwrap();

        let mut r = Cursor::new(out);
        let head = read_response_head(&mut r).unwrap();
        assert_eq!(head.status, 200);
        assert!(head.is_chunked());
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"first\n");
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"second\n");
        assert!(read_chunk(&mut r).unwrap().is_none());
    }

    #[test]
    fn interim_100_is_skipped_by_the_client() {
        let mut out = Vec::new();
        out.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
        write_response(&mut out, 200, "OK", "text/plain", &[], b"hi").unwrap();
        let mut r = Cursor::new(out);
        let head = read_response_head(&mut r).unwrap();
        assert_eq!(head.status, 200);
    }
}
