//! Readiness primitives for the transport's event loop — raw FFI
//! over the symbols every unix libc exports (`poll`, `pipe`,
//! `fcntl`, `read`, `write`, `close`, `getrlimit`/`setrlimit`),
//! mirroring the [`install_sigint`](super::install_sigint) pattern:
//! no crate dependencies, just the C ABI that is always linked.
//!
//! Three pieces, each a thin safe wrapper:
//!
//! * [`poll_ready`] — one `poll(2)` call over a caller-built
//!   [`PollFd`] slice; `EINTR` (SIGINT landing mid-poll) reports as
//!   zero ready descriptors so the caller re-checks its drain flag
//!   immediately instead of finishing the timeout.
//! * [`WakePipe`] — the classic self-pipe: worker threads
//!   [`notify`](WakePipe::notify) after pushing a completion, the
//!   reactor polls the read end and [`drain`](WakePipe::drain)s it.
//!   Both ends are nonblocking, so a full pipe (64 KiB of pending
//!   wakeups) degrades to a no-op instead of blocking a worker.
//! * [`raise_nofile_limit`] — best-effort `RLIMIT_NOFILE` bump
//!   toward the hard limit, so a many-connections run is not capped
//!   at the usual 1024-descriptor soft default.

use std::io;
use std::os::raw::{c_int, c_short};

#[cfg(target_os = "macos")]
type NfdsT = std::os::raw::c_uint;
#[cfg(not(target_os = "macos"))]
type NfdsT = std::os::raw::c_ulong;

/// `struct pollfd` — identical layout on every unix.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: c_int,
    pub events: c_short,
    pub revents: c_short,
}

impl PollFd {
    pub fn new(fd: c_int, events: c_short) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    /// Readable, or in a state (`HUP`/`ERR`/`NVAL`) a read will
    /// surface as EOF/error — either way the owner should read.
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR | POLLNVAL) != 0
    }

    pub fn writable(&self) -> bool {
        self.revents & POLLOUT != 0
    }
}

pub const POLLIN: c_short = 0x001;
pub const POLLOUT: c_short = 0x004;
pub const POLLERR: c_short = 0x008;
pub const POLLHUP: c_short = 0x010;
pub const POLLNVAL: c_short = 0x020;

#[cfg(target_os = "macos")]
const O_NONBLOCK: c_int = 0x0004;
#[cfg(not(target_os = "macos"))]
const O_NONBLOCK: c_int = 0o4000;

const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;

#[cfg(target_os = "macos")]
const RLIMIT_NOFILE: c_int = 8;
#[cfg(not(target_os = "macos"))]
const RLIMIT_NOFILE: c_int = 7;

#[repr(C)]
struct RLimit {
    cur: u64,
    max: u64,
}

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout_ms: c_int) -> c_int;
    fn pipe(fds: *mut c_int) -> c_int;
    fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
    fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
}

/// One `poll(2)` round: block up to `timeout_ms` (0 = just check,
/// negative = forever) until a descriptor in `fds` is ready, and
/// return how many are.  `EINTR` returns `Ok(0)` so a signal (the
/// SIGINT drain request) hands control back to the caller at once.
pub fn poll_ready(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    let rc =
        unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
    if rc >= 0 {
        return Ok(rc as usize);
    }
    let err = io::Error::last_os_error();
    if err.kind() == io::ErrorKind::Interrupted {
        return Ok(0);
    }
    Err(err)
}

fn set_nonblocking(fd: c_int) -> io::Result<()> {
    let flags = unsafe { fcntl(fd, F_GETFL, 0) };
    if flags < 0 {
        return Err(io::Error::last_os_error());
    }
    if unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) } < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Self-pipe wakeup: any thread [`notify`](WakePipe::notify)s, the
/// reactor polls [`read_fd`](WakePipe::read_fd) and
/// [`drain`](WakePipe::drain)s.  Closes both ends on drop.
pub struct WakePipe {
    read_fd: c_int,
    write_fd: c_int,
}

impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        let mut fds = [0 as c_int; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
            return Err(io::Error::last_os_error());
        }
        let wp = WakePipe { read_fd: fds[0], write_fd: fds[1] };
        set_nonblocking(wp.read_fd)?;
        set_nonblocking(wp.write_fd)?;
        Ok(wp)
    }

    /// The end the reactor polls (`POLLIN` = wakeups pending).
    pub fn read_fd(&self) -> c_int {
        self.read_fd
    }

    /// Wake the poller.  Never blocks: a full pipe already guarantees
    /// a pending wakeup, so the failed write is safely dropped.
    pub fn notify(&self) {
        let byte = [1u8];
        let _ = unsafe { write(self.write_fd, byte.as_ptr(), 1) };
    }

    /// Consume every pending wakeup byte (nonblocking).
    pub fn drain(&self) {
        let mut buf = [0u8; 256];
        loop {
            let n =
                unsafe { read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if n < buf.len() as isize {
                break;
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        unsafe {
            close(self.read_fd);
            close(self.write_fd);
        }
    }
}

/// Raise the soft `RLIMIT_NOFILE` toward `want` (capped at the hard
/// limit) and return the resulting soft limit.  Best-effort: the
/// caller decides whether the returned budget is enough.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let mut lim = RLimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return Err(io::Error::last_os_error());
    }
    if lim.cur >= want {
        return Ok(lim.cur);
    }
    let raised = RLimit { cur: want.min(lim.max), max: lim.max };
    if unsafe { setrlimit(RLIMIT_NOFILE, &raised) } != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(raised.cur)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wake_pipe_roundtrip_through_poll() {
        let wp = WakePipe::new().unwrap();
        let mut fds = [PollFd::new(wp.read_fd(), POLLIN)];
        assert_eq!(poll_ready(&mut fds, 0).unwrap(), 0, "idle pipe");

        wp.notify();
        wp.notify();
        let mut fds = [PollFd::new(wp.read_fd(), POLLIN)];
        assert_eq!(poll_ready(&mut fds, 1000).unwrap(), 1);
        assert!(fds[0].readable());

        wp.drain();
        let mut fds = [PollFd::new(wp.read_fd(), POLLIN)];
        assert_eq!(poll_ready(&mut fds, 0).unwrap(), 0, "drained pipe");
    }

    #[test]
    fn nofile_limit_reports_a_usable_budget() {
        let got = raise_nofile_limit(64).unwrap();
        assert!(got >= 64, "soft nofile limit {got} below the floor");
    }
}
