//! Bounded request queue with admission control.
//!
//! Producers (the load generator) stamp each request on admission;
//! consumers (workers) pull whole batches via
//! [`RequestQueue::next_batch`], which owns the batching wait logic
//! (size-triggered dispatch, flush-on-timeout, drain-on-close) so all
//! locking lives in one place.  The batching *policy* itself is the
//! pure [`decide`](crate::serve::batcher::decide) function.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::serve::batcher::{decide, BatcherConfig, Decision, FormedBatch};

/// One queued inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Flattened image row (`image_elems` f32s).
    pub image: Vec<f32>,
    /// Admission timestamp — latency is measured from here.  Set at
    /// construction and re-stamped by the queue on admission, so a
    /// closed-loop producer's backpressure wait is not billed to the
    /// request.
    pub enqueued: Instant,
    /// End-to-end budget from admission; misses are reported, not
    /// enforced.
    pub deadline: Duration,
}

impl Request {
    pub fn new(id: u64, image: Vec<f32>, deadline: Duration) -> Request {
        Request { id, image, enqueued: Instant::now(), deadline }
    }

    /// Has the admission→`done` latency blown the budget?
    pub fn missed_deadline(&self, done: Instant) -> bool {
        done.duration_since(self.enqueued) > self.deadline
    }
}

/// Counters the queue maintains under its lock.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueueStats {
    pub accepted: u64,
    pub rejected: u64,
    pub peak_depth: usize,
}

struct State {
    deque: VecDeque<Request>,
    closed: bool,
    stats: QueueStats,
}

/// MPMC queue: one load generator, `workers` batch consumers.
pub struct RequestQueue {
    capacity: usize,
    state: Mutex<State>,
    /// Signalled on enqueue/close — wakes waiting workers.
    work: Condvar,
    /// Signalled on dequeue/close — wakes a blocked producer.
    space: Condvar,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            capacity: capacity.max(1),
            state: Mutex::new(State {
                deque: VecDeque::new(),
                closed: false,
                stats: QueueStats::default(),
            }),
            work: Condvar::new(),
            space: Condvar::new(),
        }
    }

    fn admit(&self, st: &mut State, mut req: Request) {
        req.enqueued = Instant::now();
        st.deque.push_back(req);
        st.stats.accepted += 1;
        st.stats.peak_depth = st.stats.peak_depth.max(st.deque.len());
        self.work.notify_one();
    }

    /// Open-loop admission: reject (and count) when at capacity.
    pub fn try_enqueue(&self, req: Request) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.deque.len() >= self.capacity {
            st.stats.rejected += 1;
            return false;
        }
        self.admit(&mut st, req);
        true
    }

    /// Closed-loop admission: block until there is space (backpressure
    /// throttles the offered load instead of dropping).
    pub fn enqueue(&self, req: Request) -> bool {
        let mut st = self.state.lock().unwrap();
        while !st.closed && st.deque.len() >= self.capacity {
            st = self.space.wait(st).unwrap();
        }
        if st.closed {
            st.stats.rejected += 1;
            return false;
        }
        self.admit(&mut st, req);
        true
    }

    /// No more arrivals; workers drain what is queued and then stop.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.work.notify_all();
        self.space.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().deque.len()
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    pub fn stats(&self) -> QueueStats {
        self.state.lock().unwrap().stats
    }

    /// Block until a batch is ready under `cfg`, or `None` once the
    /// queue is closed and drained.  Dispatch triggers:
    ///
    /// * a full `max_batch` is waiting — dispatch immediately;
    /// * the oldest request has waited `flush_timeout` — flush the
    ///   partial batch (bounded tail latency);
    /// * the queue is closed — drain in `max_batch` chunks.
    ///
    /// Requests are popped front-first, so FIFO order is preserved
    /// through dispatch.
    pub fn next_batch(&self, cfg: &BatcherConfig) -> Option<FormedBatch> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed && st.deque.is_empty() {
                return None;
            }
            let take = if st.closed {
                st.deque.len().min(cfg.max_batch())
            } else {
                let oldest = st.deque.front().map(|r| r.enqueued);
                match decide(cfg, st.deque.len(), oldest, Instant::now()) {
                    Decision::Dispatch(take) => take,
                    Decision::WaitUntil(at) => {
                        let dur =
                            at.saturating_duration_since(Instant::now());
                        let (g, _) =
                            self.work.wait_timeout(st, dur).unwrap();
                        st = g;
                        continue;
                    }
                    Decision::WaitForWork => {
                        st = self.work.wait(st).unwrap();
                        continue;
                    }
                }
            };
            debug_assert!(take > 0, "dispatch of an empty batch");
            let mut requests = Vec::with_capacity(take);
            for _ in 0..take {
                requests.push(st.deque.pop_front().unwrap());
            }
            self.space.notify_all();
            let bucket = cfg.bucket_for(requests.len());
            return Some(FormedBatch { requests, bucket });
        }
    }
}
