//! Bounded request queue with admission control — one per
//! (model, precision) lane.
//!
//! The queue stamps each request on admission via the engine
//! [`Clock`] and exposes a *non-blocking* poll/pop API
//! ([`RequestQueue::poll`] / [`RequestQueue::pop`]): the
//! lock-and-wait coordination that used to live here (`next_batch`)
//! moved to the [`Scheduler`](crate::serve::sched::Scheduler), which
//! multiplexes many lanes over one worker pool.  The batching
//! *policy* stays the pure [`refill`](crate::serve::batcher::refill)
//! function.
//!
//! Explicit edge semantics (tested in `serve_subsystem`):
//!
//! * **Enqueue after [`close`](RequestQueue::close)** — rejected and
//!   counted in both [`QueueStats::rejected`] and
//!   [`QueueStats::rejected_closed`]; the blocking
//!   [`enqueue`](RequestQueue::enqueue) never blocks on a closed
//!   queue.
//! * **Zero-capacity queues** — admit nothing: every enqueue is
//!   rejected (and counted), blocking enqueue returns immediately
//!   instead of deadlocking.  A zero-capacity lane is a valid way to
//!   drain/disable a lane without tearing the scheduler down.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::serve::batcher::{
    refill, BatcherConfig, Decision, FormedBatch, SchedPolicy,
};
use crate::serve::clock::Clock;

/// One queued inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Flattened image row (`image_elems` f32s).
    pub image: Vec<f32>,
    /// Admission timestamp (clock-epoch offset) — latency is measured
    /// from here.  Set at construction and re-stamped by the queue on
    /// admission, so a closed-loop producer's backpressure wait is
    /// not billed to the request.
    pub enqueued: Duration,
    /// End-to-end budget from admission; misses are reported, not
    /// enforced.
    pub deadline: Duration,
}

impl Request {
    pub fn new(
        id: u64,
        image: Vec<f32>,
        deadline: Duration,
        now: Duration,
    ) -> Request {
        Request { id, image, enqueued: now, deadline }
    }

    /// Has the admission→`done` latency blown the budget?
    pub fn missed_deadline(&self, done: Duration) -> bool {
        done.saturating_sub(self.enqueued) > self.deadline
    }
}

/// Counters the queue maintains under its lock.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueueStats {
    pub accepted: u64,
    /// All rejections: full queue, zero capacity, or closed.
    pub rejected: u64,
    /// Subset of `rejected`: arrivals after [`RequestQueue::close`].
    pub rejected_closed: u64,
    pub peak_depth: usize,
}

/// What a free worker slot can get from this lane right now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePoll {
    /// `take` requests are dispatchable now (pop them with
    /// [`RequestQueue::pop`]).
    Ready(usize),
    /// Partial batch pending — poll again at this instant.
    WaitUntil(Duration),
    /// Nothing queued; more may still arrive.
    Idle,
    /// Closed and empty — nothing will ever arrive.
    Drained,
}

struct State {
    deque: VecDeque<Request>,
    closed: bool,
    stats: QueueStats,
}

/// MPMC queue: one load generator, many batch consumers (via the
/// scheduler).
pub struct RequestQueue {
    capacity: usize,
    clock: Arc<dyn Clock>,
    state: Mutex<State>,
    /// Signalled on dequeue/close — wakes a blocked producer.
    space: Condvar,
}

impl RequestQueue {
    pub fn new(capacity: usize, clock: Arc<dyn Clock>) -> RequestQueue {
        RequestQueue {
            capacity,
            clock,
            state: Mutex::new(State {
                deque: VecDeque::new(),
                closed: false,
                stats: QueueStats::default(),
            }),
            space: Condvar::new(),
        }
    }

    fn admit(&self, st: &mut State, mut req: Request) {
        req.enqueued = self.clock.now();
        st.deque.push_back(req);
        st.stats.accepted += 1;
        st.stats.peak_depth = st.stats.peak_depth.max(st.deque.len());
    }

    fn reject(st: &mut State, closed: bool) -> bool {
        st.stats.rejected += 1;
        if closed {
            st.stats.rejected_closed += 1;
        }
        false
    }

    /// Open-loop admission: reject (and count) when at capacity,
    /// closed, or zero-capacity.
    pub fn try_enqueue(&self, req: Request) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Self::reject(&mut st, true);
        }
        if st.deque.len() >= self.capacity {
            return Self::reject(&mut st, false);
        }
        self.admit(&mut st, req);
        true
    }

    /// Closed-loop admission: block until there is space
    /// (backpressure throttles the offered load instead of dropping).
    /// Returns `false` — immediately, never blocking — on a closed or
    /// zero-capacity queue.
    pub fn enqueue(&self, req: Request) -> bool {
        let mut st = self.state.lock().unwrap();
        if self.capacity == 0 {
            let closed = st.closed;
            return Self::reject(&mut st, closed);
        }
        while !st.closed && st.deque.len() >= self.capacity {
            st = self.space.wait(st).unwrap();
        }
        if st.closed {
            return Self::reject(&mut st, true);
        }
        self.admit(&mut st, req);
        true
    }

    /// No more arrivals; consumers drain what is queued and then
    /// stop.  Further enqueues are rejected and counted.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.space.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().deque.len()
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Closed *and* empty: no dispatch will ever come from this lane
    /// again.
    pub fn is_drained(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.closed && st.deque.is_empty()
    }

    pub fn stats(&self) -> QueueStats {
        self.state.lock().unwrap().stats
    }

    /// Non-blocking refill decision for this lane at `now`.  Once the
    /// queue is closed, whatever is left is dispatchable immediately
    /// in `max_batch` chunks.
    pub fn poll(
        &self,
        cfg: &BatcherConfig,
        policy: SchedPolicy,
        now: Duration,
    ) -> QueuePoll {
        let st = self.state.lock().unwrap();
        if st.deque.is_empty() {
            return if st.closed { QueuePoll::Drained } else { QueuePoll::Idle };
        }
        if st.closed {
            return QueuePoll::Ready(st.deque.len().min(cfg.max_batch()));
        }
        let oldest = st.deque.front().map(|r| r.enqueued);
        match refill(cfg, policy, st.deque.len(), oldest, now) {
            Decision::Dispatch(take) => QueuePoll::Ready(take),
            Decision::WaitUntil(at) => QueuePoll::WaitUntil(at),
            Decision::WaitForWork => QueuePoll::Idle,
        }
    }

    /// Pop up to `take` requests front-first (FIFO preserved through
    /// dispatch) and round up to the smallest bucket that fits.
    /// Returns `None` when the queue is empty.
    pub fn pop(&self, cfg: &BatcherConfig, take: usize) -> Option<FormedBatch> {
        let mut st = self.state.lock().unwrap();
        let take = take.min(st.deque.len());
        if take == 0 {
            return None;
        }
        let mut requests = Vec::with_capacity(take);
        for _ in 0..take {
            requests.push(st.deque.pop_front().unwrap());
        }
        drop(st);
        self.space.notify_all();
        let bucket = cfg.bucket_for(requests.len());
        // `dispatched` is stamped by the scheduler's dispatch point
        // (`poll_locked`), the one site that knows the dispatch time.
        Some(FormedBatch { requests, bucket, dispatched: Duration::ZERO })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::clock::VirtualClock;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn req(id: u64) -> Request {
        Request::new(id, vec![id as f32], Duration::from_secs(1), ms(0))
    }

    fn queue(capacity: usize) -> (Arc<VirtualClock>, RequestQueue) {
        let clock = Arc::new(VirtualClock::new());
        let q = RequestQueue::new(capacity, clock.clone());
        (clock, q)
    }

    #[test]
    fn admission_stamps_with_the_clock() {
        let (clock, q) = queue(8);
        clock.set(ms(7));
        assert!(q.try_enqueue(req(0)));
        let cfg = BatcherConfig::new(vec![1], ms(1)).unwrap();
        let batch = q.pop(&cfg, 1).unwrap();
        assert_eq!(batch.requests[0].enqueued, ms(7));
    }

    #[test]
    fn try_enqueue_rejects_when_full() {
        let (_clock, q) = queue(2);
        assert!(q.try_enqueue(req(0)));
        assert!(q.try_enqueue(req(1)));
        assert!(!q.try_enqueue(req(2)));
        let s = q.stats();
        assert_eq!(s.accepted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.rejected_closed, 0);
        assert_eq!(s.peak_depth, 2);
    }

    #[test]
    fn enqueue_after_close_rejects_and_counts() {
        let (_clock, q) = queue(8);
        assert!(q.try_enqueue(req(0)));
        q.close();
        assert!(!q.try_enqueue(req(1)));
        assert!(!q.enqueue(req(2))); // must not block either
        let s = q.stats();
        assert_eq!(s.accepted, 1);
        assert_eq!(s.rejected, 2);
        assert_eq!(s.rejected_closed, 2);
    }

    #[test]
    fn zero_capacity_rejects_everything_without_blocking() {
        let (_clock, q) = queue(0);
        assert!(!q.try_enqueue(req(0)));
        assert!(!q.enqueue(req(1))); // returns, never deadlocks
        let s = q.stats();
        assert_eq!(s.accepted, 0);
        assert_eq!(s.rejected, 2);
        assert_eq!(s.rejected_closed, 0);
        q.close();
        assert!(q.is_drained());
    }

    #[test]
    fn poll_reports_flush_deadline_and_ready_after_it() {
        let (clock, q) = queue(64);
        let cfg = BatcherConfig::new(vec![8], ms(40)).unwrap();
        clock.set(ms(10));
        for i in 0..3 {
            assert!(q.try_enqueue(req(i)));
        }
        // Partial batch below the smallest bucket: wait until
        // enqueue + flush_timeout, exactly.
        assert_eq!(
            q.poll(&cfg, SchedPolicy::Continuous, ms(12)),
            QueuePoll::WaitUntil(ms(50))
        );
        assert_eq!(
            q.poll(&cfg, SchedPolicy::Continuous, ms(50)),
            QueuePoll::Ready(3)
        );
        let batch = q.pop(&cfg, 3).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.bucket, 8);
        assert_eq!(batch.padding(), 5);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn close_drains_in_max_batch_chunks_fifo() {
        let (_clock, q) = queue(64);
        let cfg = BatcherConfig::new(vec![1, 2, 4, 8], ms(100)).unwrap();
        for i in 0..20 {
            assert!(q.try_enqueue(req(i)));
        }
        q.close();
        let mut ids = Vec::new();
        let mut padding = 0;
        loop {
            match q.poll(&cfg, SchedPolicy::Continuous, ms(0)) {
                QueuePoll::Ready(take) => {
                    let batch = q.pop(&cfg, take).unwrap();
                    assert!(batch.bucket >= batch.requests.len());
                    padding += batch.padding();
                    ids.extend(batch.requests.iter().map(|r| r.id));
                }
                QueuePoll::Drained => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        // 20 → chunks of 8, 8, 4: strict FIFO, no padding needed.
        assert_eq!(ids, (0..20).collect::<Vec<u64>>());
        assert_eq!(padding, 0);
    }

    #[test]
    fn poll_states_for_empty_queues() {
        let (_clock, q) = queue(8);
        let cfg = BatcherConfig::new(vec![4], ms(5)).unwrap();
        assert_eq!(
            q.poll(&cfg, SchedPolicy::Continuous, ms(0)),
            QueuePoll::Idle
        );
        q.close();
        assert_eq!(
            q.poll(&cfg, SchedPolicy::Continuous, ms(0)),
            QueuePoll::Drained
        );
        assert!(q.pop(&cfg, 4).is_none());
    }
}
