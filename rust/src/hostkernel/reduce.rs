//! Chunk-parallel elementwise add/scale — the inner kernels of the
//! tree all-reduce in [`crate::collective`].
//!
//! Both operations are pure per-element maps: `dst[i] += src[i]` and
//! `xs[i] *= k` depend only on index `i`, so splitting a slice into
//! contiguous chunk ranges across worker threads changes *where* each
//! element is computed, never *what* — the result is bitwise
//! identical for every thread count (asserted by
//! `rust/tests/hostkernel_props.rs`).  The pairwise association of
//! the all-reduce tree lives one level up, in
//! [`crate::collective::all_reduce_mean`], and is untouched by this
//! parallelism.

use super::{par_map, par_zip, thread_count};

/// `dst[i] += src[i]`, fanning out over threads for large slices.
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    add_assign_threads(dst, src, thread_count(dst.len()));
}

/// [`add_assign`] with an explicit thread count (tests pin this to
/// prove bitwise determinism across counts).
pub fn add_assign_threads(dst: &mut [f32], src: &[f32], threads: usize) {
    assert_eq!(dst.len(), src.len(), "add_assign length mismatch");
    par_zip(dst, src, threads, |d, s| {
        for (x, y) in d.iter_mut().zip(s) {
            *x += *y;
        }
    });
}

/// `xs[i] *= k`, fanning out over threads for large slices.
pub fn scale_in_place(xs: &mut [f32], k: f32) {
    scale_in_place_threads(xs, k, thread_count(xs.len()));
}

/// [`scale_in_place`] with an explicit thread count.
pub fn scale_in_place_threads(xs: &mut [f32], k: f32, threads: usize) {
    par_map(xs, threads, |c| {
        for x in c {
            *x *= k;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn add_matches_scalar_for_any_thread_count() {
        let mut rng = Rng::new(11);
        let a: Vec<f32> = (0..4097).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..4097).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut want = a.clone();
        add_assign_threads(&mut want, &b, 1);
        for threads in 2..=5 {
            let mut got = a.clone();
            add_assign_threads(&mut got, &b, threads);
            assert!(
                want.iter().zip(&got).all(|(x, y)| x.to_bits() == y.to_bits()),
                "thread count {threads} changed bits"
            );
        }
    }

    #[test]
    fn scale_matches_scalar_for_any_thread_count() {
        let mut rng = Rng::new(12);
        let a: Vec<f32> = (0..999).map(|_| rng.normal_f32(0.0, 10.0)).collect();
        let mut want = a.clone();
        scale_in_place_threads(&mut want, 0.25, 1);
        for threads in 2..=5 {
            let mut got = a.clone();
            scale_in_place_threads(&mut got, 0.25, threads);
            assert!(want
                .iter()
                .zip(&got)
                .all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }
}
