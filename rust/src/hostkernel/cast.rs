//! Batch f32 ↔ f16/bf16 casts: branchless bit-twiddling over `u32`
//! lanes.
//!
//! The scalar converters in [`crate::numerics`] are the *semantic
//! reference* — round-to-nearest-even, gradual underflow, saturation
//! to ±inf — but they branch per element, which defeats both the
//! auto-vectorizer and the branch predictor on mixed-magnitude
//! gradient data.  The lane functions here compute every range's
//! candidate (normal, subnormal, inf/nan) with straight-line integer
//! arithmetic and select by mask, so one iteration is the same
//! instruction sequence for every input; LLVM can unroll and
//! vectorize the chunked loops, and large buffers additionally fan
//! out over threads (a pure per-element map — bitwise identical for
//! any thread count, see the module determinism contract).
//!
//! Bit-exactness against `F16::from_f32` / `Bf16::from_f32` /
//! `.to_f32()` is enforced by `rust/tests/hostkernel_props.rs`
//! (exhaustive over all 2^16 half patterns in the up-cast direction;
//! every-exponent property sweeps plus directed specials — NaN
//! payloads, ±inf, subnormals, both rounding-tie directions — in the
//! down-cast direction).

use super::{par_zip, thread_count};

/// `-1` mask when `c` is true, `0` otherwise (branchless select).
#[inline(always)]
fn mask(c: bool) -> u32 {
    0u32.wrapping_sub(c as u32)
}

/// f32 bits → f16 bits, round-to-nearest-even; bit-identical to
/// [`crate::numerics::F16::from_f32`].
#[inline(always)]
pub fn f16_lane(x: u32) -> u16 {
    let sign = (x >> 16) & 0x8000;
    let ax = x & 0x7FFF_FFFF;

    // Normal range [2^-14, 65536): rebias exponent by (127-15) and
    // round the 13 dropped mantissa bits to nearest-even.  The
    // round-up carry propagates into the exponent, which is exactly
    // the RTNE behaviour at binade boundaries and at 65504→inf.
    let base = (ax >> 13).wrapping_sub(112 << 10);
    let rnd = ax & 0x1FFF;
    let normal = base.wrapping_add(
        rnd.wrapping_add(0x0FFF).wrapping_add(base & 1) >> 13,
    );

    // Subnormal range [2^-25, 2^-14): shift the 24-bit significand
    // right by 14..=24 and round-to-nearest-even on the remainder.
    // (Outside the range the shift expression is meaningless; the
    // lane is masked out below.)
    let exp32 = ax >> 23;
    let m = (ax & 0x7F_FFFF) | 0x80_0000;
    let shift = 126u32.wrapping_sub(exp32) & 31;
    let man = m >> shift;
    let round_mask = 1u32 << (shift.wrapping_sub(1) & 31);
    let rem = m & (1u32 << shift).wrapping_sub(1);
    let sub = man.wrapping_add(
        rem.wrapping_add(round_mask)
            .wrapping_sub(1)
            .wrapping_add(man & 1)
            >> shift,
    );

    // [65536, ∞]∪NaN: saturate to inf; NaN keeps its top payload bits
    // and is quieted (0x0200), matching the scalar path.
    let nan = mask(ax > 0x7F80_0000);
    let big = 0x7C00 | (nan & (0x0200 | ((ax >> 13) & 0x03FF)));

    let m_big = mask(ax >= 0x4780_0000);
    let m_norm = mask(ax >= 0x3880_0000);
    let m_sub = mask(ax >= 0x3300_0000);

    let mag = (m_big & big)
        | (!m_big & m_norm & normal)
        | (!m_big & !m_norm & m_sub & sub);
    (sign | (mag & 0xFFFF)) as u16
}

/// f16 bits → f32 bits, exact; bit-identical to
/// [`crate::numerics::F16::to_f32`].
///
/// Subnormals are renormalized by an (exact) float multiply with
/// 2^112 instead of a leading-zero count — straight-line and
/// vectorizable.  Inf/NaN get their exponent forced to 0xFF and NaNs
/// are quieted, matching the scalar path.
#[inline(always)]
pub fn f16_to_f32_lane(h: u16) -> u32 {
    let h = h as u32;
    let sign = (h & 0x8000) << 16;
    let payload = (h & 0x7FFF) << 13;
    let magic = f32::from_bits(0x7780_0000); // 2^112
    let v = (f32::from_bits(payload) * magic).to_bits();
    let infnan = mask((h & 0x7C00) == 0x7C00);
    let nan = infnan & mask((h & 0x03FF) != 0);
    sign | v | (infnan & 0x7F80_0000) | (nan & 0x0040_0000)
}

/// f32 bits → bf16 bits, round-to-nearest-even; bit-identical to
/// [`crate::numerics::Bf16::from_f32`].
#[inline(always)]
pub fn bf16_lane(x: u32) -> u16 {
    let ax = x & 0x7FFF_FFFF;
    let upper = x >> 16;
    let inc = (x & 0xFFFF).wrapping_add(0x7FFF).wrapping_add(upper & 1) >> 16;
    let normal = upper.wrapping_add(inc);
    let nanv = upper | 0x0040;
    let nan = mask(ax > 0x7F80_0000);
    ((nan & nanv) | (!nan & normal)) as u16
}

/// bf16 bits → f32 bits, exact (bf16 is f32's top half).
#[inline(always)]
pub fn bf16_to_f32_lane(b: u16) -> u32 {
    (b as u32) << 16
}

/// Cast a whole f32 slice to f16 bit patterns.
pub fn f32_to_f16_slice(src: &[f32], dst: &mut [u16]) {
    par_zip(dst, src, thread_count(src.len()), |d, s| {
        for (o, x) in d.iter_mut().zip(s) {
            *o = f16_lane(x.to_bits());
        }
    });
}

/// Cast f16 bit patterns back to f32 (exact).
pub fn f16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
    par_zip(dst, src, thread_count(src.len()), |d, s| {
        for (o, h) in d.iter_mut().zip(s) {
            *o = f32::from_bits(f16_to_f32_lane(*h));
        }
    });
}

/// Cast a whole f32 slice to bf16 bit patterns.
pub fn f32_to_bf16_slice(src: &[f32], dst: &mut [u16]) {
    par_zip(dst, src, thread_count(src.len()), |d, s| {
        for (o, x) in d.iter_mut().zip(s) {
            *o = bf16_lane(x.to_bits());
        }
    });
}

/// Cast bf16 bit patterns back to f32 (exact).
pub fn bf16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
    par_zip(dst, src, thread_count(src.len()), |d, s| {
        for (o, b) in d.iter_mut().zip(s) {
            *o = f32::from_bits(bf16_to_f32_lane(*b));
        }
    });
}

/// Round-trip every element through f16 in place (fused down+up —
/// one traversal, no staging buffer).
pub fn quantize_f16_slice(xs: &mut [f32]) {
    super::par_map(xs, thread_count(xs.len()), |c| {
        for x in c {
            *x = f32::from_bits(f16_to_f32_lane(f16_lane(x.to_bits())));
        }
    });
}

/// Round-trip every element through bf16 in place.
pub fn quantize_bf16_slice(xs: &mut [f32]) {
    super::par_map(xs, thread_count(xs.len()), |c| {
        for x in c {
            *x = f32::from_bits(bf16_to_f32_lane(bf16_lane(x.to_bits())));
        }
    });
}

/// Append `src` cast to little-endian f16 bytes onto `out`
/// (checkpoint save path).
pub fn f32_to_f16_bytes(src: &[f32], out: &mut Vec<u8>) {
    out.reserve(src.len() * 2);
    for x in src {
        out.extend_from_slice(&f16_lane(x.to_bits()).to_le_bytes());
    }
}

/// Append `src` cast to little-endian bf16 bytes onto `out`.
pub fn f32_to_bf16_bytes(src: &[f32], out: &mut Vec<u8>) {
    out.reserve(src.len() * 2);
    for x in src {
        out.extend_from_slice(&bf16_lane(x.to_bits()).to_le_bytes());
    }
}

/// `(underflows, overflows)` a cast to f16 would produce: nonzero
/// finite values that flush to ±0, and finite values that saturate to
/// ±inf.  One branchless counting pass — the diagnostics kernel
/// behind [`crate::numerics::underflow_fraction`] /
/// [`crate::numerics::overflow_count`].
pub fn f16_under_overflow_counts(xs: &[f32]) -> (usize, usize) {
    count_under_overflow(xs, |bits| {
        let h = f16_lane(bits) as u32;
        ((h & 0x7FFF) == 0, (h & 0x7C00) == 0x7C00)
    })
}

/// f16 counterpart for bf16 — see [`f16_under_overflow_counts`].
pub fn bf16_under_overflow_counts(xs: &[f32]) -> (usize, usize) {
    count_under_overflow(xs, |bits| {
        let b = bf16_lane(bits) as u32;
        ((b & 0x7FFF) == 0, (b & 0x7F80) == 0x7F80)
    })
}

/// Shared counting loop: `classify(bits)` returns (is_zero_after_cast,
/// is_nonfinite_after_cast) for the half format.  Integer counts are
/// associative, so chunk partials sum deterministically in chunk
/// order regardless of thread count.
fn count_under_overflow<C>(xs: &[f32], classify: C) -> (usize, usize)
where
    C: Fn(u32) -> (bool, bool) + Send + Sync + Copy,
{
    let chunk_counts = |c: &[f32]| -> (usize, usize) {
        let (mut under, mut over) = (0usize, 0usize);
        for x in c {
            let bits = x.to_bits();
            let ax = bits & 0x7FFF_FFFF;
            let (casts_to_zero, casts_to_nonfinite) = classify(bits);
            under += (casts_to_zero && ax != 0) as usize;
            over += (casts_to_nonfinite && ax < 0x7F80_0000) as usize;
        }
        (under, over)
    };
    let threads = thread_count(xs.len());
    if threads <= 1 {
        return chunk_counts(xs);
    }
    let chunk = xs.len().div_ceil(threads);
    let partials: Vec<(usize, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = xs
            .chunks(chunk)
            .map(|c| s.spawn(move || chunk_counts(c)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("count thread panicked"))
            .collect()
    });
    partials
        .into_iter()
        .fold((0, 0), |(u, o), (cu, co)| (u + cu, o + co))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::{Bf16, F16};

    #[test]
    fn lane_matches_scalar_on_specials() {
        for &f in &[
            0.0f32,
            -0.0,
            1.0,
            -2.0,
            0.5,
            65504.0,
            65519.0,
            65520.0,
            65536.0,
            1e9,
            -1e9,
            1e-8,
            -1e-8,
            3.1e-8,
            2.9802322e-8,
            f32::MAX,
            f32::MIN_POSITIVE,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            1.0 + 2f32.powi(-11),
            1.0 + 3.0 * 2f32.powi(-11),
        ] {
            let bits = f.to_bits();
            assert_eq!(
                f16_lane(bits),
                F16::from_f32(f).0,
                "f16 lane mismatch for {f} ({bits:#010x})"
            );
            assert_eq!(
                bf16_lane(bits),
                Bf16::from_f32(f).0,
                "bf16 lane mismatch for {f} ({bits:#010x})"
            );
        }
    }

    #[test]
    fn upcast_exhaustive_matches_scalar() {
        for h in 0u16..=u16::MAX {
            assert_eq!(
                f16_to_f32_lane(h),
                F16(h).to_f32().to_bits(),
                "f16→f32 mismatch at {h:#06x}"
            );
            assert_eq!(
                bf16_to_f32_lane(h),
                Bf16(h).to_f32().to_bits(),
                "bf16→f32 mismatch at {h:#06x}"
            );
        }
    }

    #[test]
    fn slice_roundtrip() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.37).collect();
        let mut half = vec![0u16; xs.len()];
        let mut back = vec![0f32; xs.len()];
        f32_to_f16_slice(&xs, &mut half);
        f16_to_f32_slice(&half, &mut back);
        for (x, b) in xs.iter().zip(&back) {
            assert_eq!(F16::from_f32(*x).to_f32().to_bits(), b.to_bits());
        }
    }

    #[test]
    fn counting_kernels_match_reference() {
        let xs = [1e-8f32, 1.0, 70000.0, 0.0, f32::INFINITY, f32::NAN, -1e-9];
        let (u16_, o16) = f16_under_overflow_counts(&xs);
        assert_eq!(u16_, 2); // 1e-8 and -1e-9 flush in f16
        assert_eq!(o16, 1); // 70000 saturates in f16
        let (ub, ob) = bf16_under_overflow_counts(&xs);
        assert_eq!(ub, 0);
        assert_eq!(ob, 0);
    }

    #[test]
    fn bytes_are_little_endian_pairs() {
        let mut out = Vec::new();
        f32_to_f16_bytes(&[1.0, -2.0], &mut out);
        assert_eq!(out, vec![0x00, 0x3C, 0x00, 0xC0]);
        out.clear();
        f32_to_bf16_bytes(&[1.0], &mut out);
        assert_eq!(out, vec![0x80, 0x3F]);
    }
}
