//! Vectorized host-compute kernels: the coordinator's hot byte paths.
//!
//! Mixed precision only pays when the conversion and scaling machinery
//! is essentially free (Micikevicius et al. 2017; paper §2).  The
//! compiled graphs get that for free from XLA; the *host* side of this
//! reproduction — checkpoint casts, gradient scans, the DDP
//! all-reduce, serve-batch packing — originally walked every buffer
//! one `f32` at a time through branchy scalar code and allocated fresh
//! vectors each step.  This module is the replacement substrate:
//!
//! * [`cast`] — whole-slice f32↔f16/bf16 conversions as branchless
//!   bit-twiddling over `u32` lanes (auto-vectorizable chunked loops),
//!   bit-identical to the scalar [`crate::numerics::F16`] /
//!   [`crate::numerics::Bf16`] round-to-nearest-even implementations
//!   (property-tested in `rust/tests/hostkernel_props.rs`).
//! * [`scan`] — the fused gradient scan: unscale by `1/S`, accumulate
//!   [`crate::numerics::TensorStats`] and the finiteness flag in one
//!   traversal instead of an unscale pass followed by a stats pass.
//! * [`reduce`] — chunk-parallel elementwise add/scale used by the
//!   tree all-reduce in [`crate::collective`].
//! * [`pool`] — a [`pool::BufferPool`] arena of reusable buffers so
//!   steady-state step/serve loops stop allocating.
//!
//! # Determinism contract
//!
//! Every kernel here is **bitwise-deterministic across runs and across
//! thread counts**:
//!
//! * Casts and elementwise add/scale are pure per-element maps — each
//!   output element depends only on its own inputs, so any contiguous
//!   chunking over any number of worker threads produces identical
//!   bytes.
//! * Reductions keep a *fixed association*.  The all-reduce keeps the
//!   pairwise tree order over shards (`(g0+g1) + (g2+g3)`) and only
//!   parallelizes the elementwise adds inside a pair, which preserves
//!   per-element association exactly.  The fused gradient scan
//!   accumulates its `f64` mean in strict element order on one thread
//!   (a chunked mean would round differently), which is why it is the
//!   one kernel without a threaded path — its win is halving the
//!   number of traversals, not threading.
//!
//! Threaded paths engage only above [`PAR_MIN_ELEMS`] elements so
//! small tensors never pay thread-spawn latency; the cut-over and the
//! thread count change *which cores* compute an element, never *what*
//! is computed.

pub mod cast;
pub mod pool;
pub mod reduce;
pub mod scan;

pub use cast::{
    bf16_to_f32_slice, f16_to_f32_slice, f32_to_bf16_slice,
    f32_to_f16_slice, quantize_bf16_slice, quantize_f16_slice,
};
pub use pool::{BufferPool, PoolStats};
pub use reduce::{add_assign, scale_in_place};
pub use scan::{
    fused_unscale_stats, fused_unscale_stats_tensors, stats_tensors,
};

/// Minimum slice length before a kernel considers fanning out over
/// threads; below this, thread-spawn latency dwarfs the work.
pub const PAR_MIN_ELEMS: usize = 1 << 18;

/// Worker threads to use for `len` elements: 1 below the threshold,
/// otherwise the hardware parallelism capped so every thread keeps at
/// least half a threshold's worth of work.
pub(crate) fn thread_count(len: usize) -> usize {
    if len < PAR_MIN_ELEMS {
        return 1;
    }
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    hw.min(8).min(len / (PAR_MIN_ELEMS / 2)).max(1)
}

/// Apply `f` to equal contiguous chunks of `dst`/`src` on `threads`
/// scoped threads.  `f` must be a pure per-element map for the
/// determinism contract to hold (it is, for every caller here).
pub(crate) fn par_zip<A, B, F>(dst: &mut [A], src: &[B], threads: usize, f: F)
where
    A: Send,
    B: Sync,
    F: Fn(&mut [A], &[B]) + Send + Sync + Copy,
{
    assert_eq!(dst.len(), src.len(), "par_zip length mismatch");
    if threads <= 1 || dst.len() < 2 {
        f(dst, src);
        return;
    }
    let chunk = dst.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (d, sr) in dst.chunks_mut(chunk).zip(src.chunks(chunk)) {
            s.spawn(move || f(d, sr));
        }
    });
}

/// In-place variant of [`par_zip`] for unary per-element maps.
pub(crate) fn par_map<A, F>(xs: &mut [A], threads: usize, f: F)
where
    A: Send,
    F: Fn(&mut [A]) + Send + Sync + Copy,
{
    if threads <= 1 || xs.len() < 2 {
        f(xs);
        return;
    }
    let chunk = xs.len().div_ceil(threads);
    std::thread::scope(|s| {
        for c in xs.chunks_mut(chunk) {
            s.spawn(move || f(c));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_count_small_is_one() {
        assert_eq!(thread_count(0), 1);
        assert_eq!(thread_count(PAR_MIN_ELEMS - 1), 1);
    }

    #[test]
    fn thread_count_large_bounded() {
        let t = thread_count(1 << 24);
        assert!(t >= 1 && t <= 8);
    }

    #[test]
    fn par_zip_covers_every_element() {
        for threads in 1..=5 {
            let mut dst = vec![0u32; 1000];
            let src: Vec<u32> = (0..1000).collect();
            par_zip(&mut dst, &src, threads, |d, s| {
                for (x, y) in d.iter_mut().zip(s) {
                    *x = y + 1;
                }
            });
            assert!(dst.iter().enumerate().all(|(i, &x)| x == i as u32 + 1));
        }
    }

    #[test]
    fn par_map_covers_every_element() {
        for threads in 1..=5 {
            let mut xs = vec![1u32; 777];
            par_map(&mut xs, threads, |c| {
                for x in c {
                    *x += 1;
                }
            });
            assert!(xs.iter().all(|&x| x == 2));
        }
    }
}
