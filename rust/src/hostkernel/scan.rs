//! Fused gradient scan: unscale + statistics + finiteness in one
//! traversal.
//!
//! The paper's §2.1 recipe needs three things from every gradient
//! buffer after the backward pass: the gradients divided by the loss
//! scale, a finiteness verdict, and (in diagnostics mode) magnitude
//! statistics.  Done naively that is an unscale pass followed by a
//! [`crate::numerics::tensor_stats`] pass — two full traversals of a
//! buffer that usually misses cache.  This kernel does both in one
//! pass, classifying each element from its bit pattern instead of
//! through `is_nan`/`is_infinite` calls.
//!
//! # Exactness
//!
//! The result is **bit-identical** to `unscale-then-tensor_stats`
//! (property-tested): the per-element operations are the same f32
//! multiply and f32 comparisons in the same element order, and the
//! `mean_abs` numerator accumulates in `f64` in strict element order
//! on a single thread.  That sequential accumulation is deliberate —
//! chunked partial sums would round differently — so this is the one
//! hostkernel without a threaded path; its win is one traversal
//! instead of two (see the module determinism contract).

use crate::numerics::TensorStats;

/// RTNE f16 saturation boundary: an f32 with `|x| ≥ 65520` rounds to
/// ±inf when cast to binary16 (65520 is exactly halfway between the
/// f16 max 65504 and the would-be 65536; ties round away to inf).
pub const F16_SATURATE: f32 = 65520.0;

/// RTNE f16 flush boundary: a nonzero f32 with `|x| ≤ 2⁻²⁵` rounds to
/// ±0 when cast to binary16 (2⁻²⁵ is exactly halfway between 0 and
/// the min subnormal 2⁻²⁴; the tie rounds to the even 0).
pub const F16_FLUSH: f32 = 2.9802322387695312e-8;

/// Count how many elements of `xs` would flush to zero / saturate to
/// ±inf if *scaled by `scale`* and cast to f16 — the per-group
/// dynamic-range census the adaptive scaling policy consumes
/// ([`crate::scaling::adaptive`]).  Returns `(underflow, overflow)`.
///
/// `scale` must be a positive power of two (the scaling policies only
/// produce those), which makes `threshold / scale` exact, so the
/// comparisons are bit-equivalent to casting `x·scale` elementwise.
/// NaNs count toward neither side (the finiteness flag covers them);
/// infs land in the overflow count.
pub fn scaled_f16_census(xs: &[f32], scale: f32) -> (u64, u64) {
    debug_assert!(scale > 0.0 && scale.log2().fract() == 0.0);
    let flush = F16_FLUSH / scale;
    let sat = F16_SATURATE / scale;
    let mut under = 0u64;
    let mut over = 0u64;
    for &x in xs {
        let a = f32::from_bits(x.to_bits() & 0x7FFF_FFFF);
        under += (a > 0.0 && a <= flush) as u64;
        over += (a >= sat) as u64;
    }
    (under, over)
}

/// Streaming accumulator matching [`crate::numerics::tensor_stats`]'s
/// update rules exactly; feed slices in order, then [`finish`].
///
/// [`finish`]: StatsAcc::finish
#[derive(Debug, Clone)]
pub struct StatsAcc {
    count: usize,
    min_abs_nonzero: f32,
    max_abs: f32,
    sum_abs: f64,
    zeros: usize,
    infs: usize,
    nans: usize,
}

impl Default for StatsAcc {
    fn default() -> Self {
        StatsAcc {
            count: 0,
            min_abs_nonzero: f32::INFINITY,
            max_abs: 0.0,
            sum_abs: 0.0,
            zeros: 0,
            infs: 0,
            nans: 0,
        }
    }
}

impl StatsAcc {
    /// Unscale `xs` by `inv_scale` in place and fold the results into
    /// the running statistics — one traversal.
    pub fn feed_unscale(&mut self, xs: &mut [f32], inv_scale: f32) {
        self.count += xs.len();
        for x in xs.iter_mut() {
            let y = *x * inv_scale;
            *x = y;
            self.fold(y);
        }
    }

    /// Fold a read-only slice into the running statistics (no
    /// unscale, no writes) — for stats over a buffer that must stay
    /// untouched, e.g. the reduced gradient right before the
    /// optimizer consumes it.
    pub fn feed(&mut self, xs: &[f32]) {
        self.count += xs.len();
        for &y in xs {
            self.fold(y);
        }
    }

    #[inline(always)]
    fn fold(&mut self, y: f32) {
        let ax = y.to_bits() & 0x7FFF_FFFF;
        if ax >= 0x7F80_0000 {
            // non-finite: rare, so one predictable branch
            if ax == 0x7F80_0000 {
                self.infs += 1;
            } else {
                self.nans += 1;
            }
            return;
        }
        let a = f32::from_bits(ax); // |y|
        if ax == 0 {
            self.zeros += 1;
        } else if a < self.min_abs_nonzero {
            self.min_abs_nonzero = a;
        }
        if a > self.max_abs {
            self.max_abs = a;
        }
        self.sum_abs += a as f64;
    }

    /// Close out into a [`TensorStats`] (same fields `tensor_stats`
    /// would have produced over the concatenation of the fed slices).
    pub fn finish(self) -> TensorStats {
        let mean_abs = if self.count > 0 {
            (self.sum_abs / self.count as f64) as f32
        } else {
            0.0
        };
        TensorStats {
            count: self.count,
            finite: self.infs == 0 && self.nans == 0,
            min_abs_nonzero: self.min_abs_nonzero,
            max_abs: self.max_abs,
            mean_abs,
            zeros: self.zeros,
            infs: self.infs,
            nans: self.nans,
        }
    }
}

/// Unscale `xs` by `inv_scale` in place and return its statistics —
/// bit-identical to `for x in xs { *x *= inv_scale }` followed by
/// [`crate::numerics::tensor_stats`], in one traversal.
pub fn fused_unscale_stats(xs: &mut [f32], inv_scale: f32) -> TensorStats {
    let mut acc = StatsAcc::default();
    acc.feed_unscale(xs, inv_scale);
    acc.finish()
}

/// Multi-tensor variant: unscale every tensor in place and return the
/// statistics of their concatenation (the whole-gradient view the DDP
/// trainer and the loss-scaling diagnostics want).
pub fn fused_unscale_stats_tensors(
    tensors: &mut [Vec<f32>],
    inv_scale: f32,
) -> TensorStats {
    let mut acc = StatsAcc::default();
    for t in tensors.iter_mut() {
        acc.feed_unscale(t, inv_scale);
    }
    acc.finish()
}

/// Read-only multi-tensor statistics — same single-traversal
/// accumulator without the unscale/write (identical result to
/// [`fused_unscale_stats_tensors`] with `inv_scale = 1.0`, but the
/// buffers are guaranteed untouched).
pub fn stats_tensors(tensors: &[Vec<f32>]) -> TensorStats {
    let mut acc = StatsAcc::default();
    for t in tensors {
        acc.feed(t);
    }
    acc.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::tensor_stats;

    fn reference(xs: &mut [f32], inv: f32) -> TensorStats {
        for x in xs.iter_mut() {
            *x *= inv;
        }
        tensor_stats(xs)
    }

    fn assert_stats_eq(a: &TensorStats, b: &TensorStats) {
        assert_eq!(a.count, b.count);
        assert_eq!(a.finite, b.finite);
        assert_eq!(
            a.min_abs_nonzero.to_bits(),
            b.min_abs_nonzero.to_bits()
        );
        assert_eq!(a.max_abs.to_bits(), b.max_abs.to_bits());
        assert_eq!(a.mean_abs.to_bits(), b.mean_abs.to_bits());
        assert_eq!(a.zeros, b.zeros);
        assert_eq!(a.infs, b.infs);
        assert_eq!(a.nans, b.nans);
    }

    #[test]
    fn matches_reference_with_specials() {
        let base = [
            0.0f32,
            -0.0,
            1.0,
            -2.5,
            1e-38,
            -3e38,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            65504.0,
            5.9e-8,
        ];
        for inv in [1.0f32, 0.5, 2.0, 1.0 / 32768.0] {
            let mut a = base;
            let mut b = base;
            let got = fused_unscale_stats(&mut a, inv);
            let want = reference(&mut b, inv);
            assert_eq!(a.map(f32::to_bits), b.map(f32::to_bits));
            assert_stats_eq(&got, &want);
        }
    }

    #[test]
    fn empty_matches_reference() {
        let got = fused_unscale_stats(&mut [], 0.5);
        let want = tensor_stats(&[]);
        assert_stats_eq(&got, &want);
    }

    #[test]
    fn read_only_feed_matches_tensor_stats() {
        let tensors = vec![
            vec![1.0f32, -2.0, f32::INFINITY],
            vec![0.0, -0.0, 1e-40, f32::NAN],
        ];
        let flat: Vec<f32> = tensors.iter().flatten().copied().collect();
        let got = stats_tensors(&tensors);
        let want = tensor_stats(&flat);
        assert_stats_eq(&got, &want);
        // buffers untouched by construction (shared reference), and
        // the result agrees with the mutating scan at inv=1.
        let mut mutated = tensors.clone();
        let also = fused_unscale_stats_tensors(&mut mutated, 1.0);
        assert_stats_eq(&got, &also);
    }

    #[test]
    fn census_matches_elementwise_cast() {
        use crate::numerics::{FloatFormat, F16};
        let mut rng = crate::util::rng::Rng::new(11);
        for &scale in &[1.0f32, 8.0, 1024.0, 32768.0, 16_777_216.0] {
            let xs: Vec<f32> = (0..4096)
                .map(|_| {
                    // span the whole dynamic range, signs included
                    let mag = 10f32.powf(rng.next_f64() as f32 * 50.0 - 42.0);
                    if rng.next_f64() < 0.5 { -mag } else { mag }
                })
                .collect();
            let (under, over) = scaled_f16_census(&xs, scale);
            let mut want_under = 0u64;
            let mut want_over = 0u64;
            for &x in &xs {
                let y = F16::from_f32(x * scale).to_f32();
                if x != 0.0 && x.is_finite() && y == 0.0 {
                    want_under += 1;
                }
                if (x * scale).is_finite() && y.is_infinite() {
                    want_over += 1;
                }
            }
            assert_eq!((under, over), (want_under, want_over), "scale {scale}");
        }
    }

    #[test]
    fn census_boundaries_and_specials() {
        // Exactly the RTNE tie points, at scale 1.
        let xs = [
            F16_FLUSH,            // ties to zero → underflow
            F16_FLUSH * 1.0001,   // rounds to the min subnormal
            F16_SATURATE,         // ties away to inf → overflow
            65504.0,              // f16 max, survives
            f32::INFINITY,        // overflow side
            f32::NAN,             // neither
            0.0,                  // zero is not an underflow
        ];
        assert_eq!(scaled_f16_census(&xs, 1.0), (1, 2));
        // A scale of 2^4 pushes 65504/16 over and rescues nothing.
        assert_eq!(scaled_f16_census(&[65504.0 / 16.0], 16.0), (0, 0));
        assert_eq!(scaled_f16_census(&[65520.0 / 16.0], 16.0), (0, 1));
    }

    #[test]
    fn multi_tensor_equals_concatenation() {
        let mut tensors = vec![vec![1.0f32, -2.0], vec![0.0, 3e-39, 7.5]];
        let mut flat: Vec<f32> =
            tensors.iter().flatten().copied().collect();
        let got = fused_unscale_stats_tensors(&mut tensors, 0.25);
        let want = reference(&mut flat, 0.25);
        assert_stats_eq(&got, &want);
    }
}
