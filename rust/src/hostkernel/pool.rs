//! Buffer pool: reusable host buffers for steady-state loops.
//!
//! The DDP step loop, the checkpoint writer and the serve batcher all
//! stage data through short-lived `Vec`s — per step, per leaf, per
//! batch.  Those allocations are individually cheap but recur at
//! request/step rate and fragment the heap under sustained load.  The
//! [`BufferPool`] is a trivially simple arena: typed stacks of
//! retired buffers, handed back out *empty but with their capacity
//! intact*, so a loop that cycles same-sized buffers stops touching
//! the allocator after warm-up.
//!
//! Buffers carry their natural element alignment (4 bytes for
//! f32/i32, 2 for u16) — exactly what the chunked hostkernel loops
//! and `Literal::create_from_shape_and_untyped_data` require.
//!
//! `take_*` returns an **empty** vector with at least the requested
//! capacity (callers push/extend into it); `put_*` retires a buffer
//! for reuse.  The pool is `Mutex`-guarded and shared freely across
//! threads; each stack is capped so a burst cannot pin unbounded
//! memory.

use std::sync::{Mutex, OnceLock};

/// Retired buffers kept per type — beyond this, returned buffers are
/// simply dropped.
const MAX_POOLED: usize = 64;

/// Largest single buffer the pool will retain (bytes).  Anything
/// bigger is dropped on `put` so a burst of huge buffers cannot pin
/// unbounded memory in the process-global pool for the rest of the
/// process lifetime.  64 MiB comfortably covers the largest steady
/// buffers in the repo (a vit_base serve bucket of 64 padded 224²
/// images ≈ 38 MiB) while bounding the worst case.
const MAX_POOLED_BYTES: usize = 64 << 20;

/// Occupancy/traffic counters (observability for the benches).
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// `take` calls satisfied by a recycled buffer.
    pub hits: u64,
    /// `take` calls that had to allocate fresh.
    pub misses: u64,
    /// Buffers accepted back by `put`.
    pub recycled: u64,
}

#[derive(Default)]
struct Shelf<T> {
    bufs: Vec<Vec<T>>,
}

impl<T> Shelf<T> {
    fn take(&mut self, capacity: usize, stats: &mut PoolStats) -> Vec<T> {
        // Last-in first-out keeps the hottest (cache-warm) buffer on
        // top; capacity is grown by the caller's pushes if short.
        match self.bufs.pop() {
            Some(mut b) => {
                stats.hits += 1;
                b.clear();
                if b.capacity() < capacity {
                    b.reserve(capacity - b.len());
                }
                b
            }
            None => {
                stats.misses += 1;
                Vec::with_capacity(capacity)
            }
        }
    }

    fn put(&mut self, buf: Vec<T>, stats: &mut PoolStats) {
        let bytes = buf.capacity() * std::mem::size_of::<T>();
        if bytes > 0 && bytes <= MAX_POOLED_BYTES && self.bufs.len() < MAX_POOLED
        {
            stats.recycled += 1;
            self.bufs.push(buf);
        }
    }
}

struct Inner {
    f32s: Shelf<f32>,
    i32s: Shelf<i32>,
    u16s: Shelf<u16>,
    bytes: Shelf<u8>,
    stats: PoolStats,
}

/// Thread-safe arena of reusable `Vec` buffers; see the module docs.
pub struct BufferPool {
    inner: Mutex<Inner>,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool {
            inner: Mutex::new(Inner {
                f32s: Shelf::default(),
                i32s: Shelf::default(),
                u16s: Shelf::default(),
                bytes: Shelf::default(),
                stats: PoolStats::default(),
            }),
        }
    }

    /// The process-wide pool the trainers, checkpointing and serve
    /// paths share.
    pub fn global() -> &'static BufferPool {
        static GLOBAL: OnceLock<BufferPool> = OnceLock::new();
        GLOBAL.get_or_init(BufferPool::new)
    }

    pub fn take_f32(&self, capacity: usize) -> Vec<f32> {
        let g = &mut *self.inner.lock().unwrap();
        g.f32s.take(capacity, &mut g.stats)
    }

    pub fn put_f32(&self, buf: Vec<f32>) {
        let g = &mut *self.inner.lock().unwrap();
        g.f32s.put(buf, &mut g.stats);
    }

    pub fn take_i32(&self, capacity: usize) -> Vec<i32> {
        let g = &mut *self.inner.lock().unwrap();
        g.i32s.take(capacity, &mut g.stats)
    }

    pub fn put_i32(&self, buf: Vec<i32>) {
        let g = &mut *self.inner.lock().unwrap();
        g.i32s.put(buf, &mut g.stats);
    }

    pub fn take_u16(&self, capacity: usize) -> Vec<u16> {
        let g = &mut *self.inner.lock().unwrap();
        g.u16s.take(capacity, &mut g.stats)
    }

    pub fn put_u16(&self, buf: Vec<u16>) {
        let g = &mut *self.inner.lock().unwrap();
        g.u16s.put(buf, &mut g.stats);
    }

    pub fn take_u8(&self, capacity: usize) -> Vec<u8> {
        let g = &mut *self.inner.lock().unwrap();
        g.bytes.take(capacity, &mut g.stats)
    }

    pub fn put_u8(&self, buf: Vec<u8>) {
        let g = &mut *self.inner.lock().unwrap();
        g.bytes.put(buf, &mut g.stats);
    }

    pub fn stats(&self) -> PoolStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_empty_with_capacity() {
        let pool = BufferPool::new();
        let b = pool.take_f32(100);
        assert!(b.is_empty());
        assert!(b.capacity() >= 100);
    }

    #[test]
    fn recycles_capacity() {
        let pool = BufferPool::new();
        let mut b = pool.take_f32(0);
        b.extend_from_slice(&[1.0; 500]);
        let cap = b.capacity();
        pool.put_f32(b);
        let again = pool.take_f32(10);
        assert!(again.is_empty());
        assert!(again.capacity() >= cap.min(500));
        let s = pool.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert_eq!(s.recycled, 1);
    }

    #[test]
    fn zero_capacity_buffers_are_dropped() {
        let pool = BufferPool::new();
        pool.put_u8(Vec::new());
        assert_eq!(pool.stats().recycled, 0);
    }

    #[test]
    fn shelves_are_typed() {
        let pool = BufferPool::new();
        let mut b = pool.take_i32(4);
        b.push(7);
        pool.put_i32(b);
        // u16 shelf is independent: this take must miss.
        let _ = pool.take_u16(4);
        let s = pool.stats();
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 0);
    }

    #[test]
    fn pool_cap_bounds_retention() {
        let pool = BufferPool::new();
        for _ in 0..(MAX_POOLED + 10) {
            pool.put_u8(vec![0u8; 8]);
        }
        assert_eq!(pool.stats().recycled, MAX_POOLED as u64);
    }

    #[test]
    fn oversized_buffers_are_not_retained() {
        let pool = BufferPool::new();
        pool.put_u8(Vec::with_capacity(MAX_POOLED_BYTES + 1));
        assert_eq!(pool.stats().recycled, 0);
        pool.put_f32(Vec::with_capacity(
            MAX_POOLED_BYTES / std::mem::size_of::<f32>() + 1,
        ));
        assert_eq!(pool.stats().recycled, 0);
    }
}
